//! The agentic chain tier: sessions of dependent steps under ONE
//! chain-level budget.
//!
//! The paper motivates latency-aware allocation with *agentic workflows
//! where models issue multiple dependent queries*; the unit that
//! matters there is the **chain**, not the step (goodput = fraction of
//! chains fully correct AND under the chain SLO). A [`ChainSpec`] is a
//! session of N dependent steps: step k+1's prompt is the step
//! template re-seeded with step k's *selected* answer
//! ([`ChainProblem::with_first`]), so errors cascade exactly the way an
//! agent's do. Steps mix the modular-arithmetic and max-value domains,
//! so per-step difficulty is genuinely heterogeneous and the router has
//! something to exploit.
//!
//! The chain budget is split across steps and *re-split after every
//! completion* by [`ChainAllocator`]: an early step that finishes cheap
//! banks its surplus, the next slice widens, and
//! `Router::select_budgeted` can upgrade a later, harder step to a
//! stronger strategy. Execution lives in the serving driver
//! ([`crate::server::driver::run_traffic`], stepped/interleaved) and in
//! [`run_chain_blocking`] (the blocking reference used by equivalence
//! tests); trace-driven replay ([`parse_trace`]) makes runs exactly
//! reproducible. See `docs/chains.md`.

use crate::data::Query;
use crate::error::{Error, Result};
use crate::router::{ChainAllocator, Grant};
use crate::server::driver::{route, Mode};
use crate::server::loadgen::{arrival_gap_s, Arrivals, Request};
use crate::strategies::{Budget, Executor};
use crate::taskgen::arith::MODULUS;
use crate::taskgen::{ChainProblem, MaxProblem, Problem};
use crate::util::json::{self, Value};
use crate::util::rng::Rng;

/// Chain lengths are heavy-tailed within these bounds (sessions of a
/// couple of steps dominate; long sessions are rare but present).
pub const MIN_CHAIN_STEPS: usize = 2;
/// See [`MIN_CHAIN_STEPS`].
pub const MAX_CHAIN_STEPS: usize = 6;

/// One scheduled chain: N dependent step templates under one
/// chain-level budget. Step 0 runs its template verbatim; step k+1's
/// template is re-seeded with step k's selected answer at admission.
#[derive(Debug, Clone)]
pub struct ChainSpec {
    pub id: String,
    /// Offset from run start, ms.
    pub arrival_ms: f64,
    /// Chain-level totals (deadline headroom from arrival, token cap) —
    /// the pool [`ChainAllocator`] splits across steps.
    pub budget: Budget,
    pub steps: Vec<ChainProblem>,
}

// [`Budget`] carries a non-comparable cancel flag, so spec equality
// (trace roundtrip tests) compares its two limit fields explicitly.
impl PartialEq for ChainSpec {
    fn eq(&self, other: &ChainSpec) -> bool {
        self.id == other.id
            && self.arrival_ms == other.arrival_ms
            && self.budget.deadline_ms == other.budget.deadline_ms
            && self.budget.max_tokens == other.budget.max_tokens
            && self.steps == other.steps
    }
}

/// Difficulty weight of one step for the budget split: its CoT length
/// scaled by the domain's relative slip difficulty, so an 8-step
/// arithmetic chain claims a larger slice than a 3-item max chain.
pub fn step_weight(p: &ChainProblem) -> f64 {
    (p.k() as f64 * p.slip_factor()).max(0.5)
}

impl ChainSpec {
    /// The allocator for this chain's budget, weighted by per-step
    /// difficulty.
    pub fn allocator(&self) -> ChainAllocator {
        let weights: Vec<f64> = self.steps.iter().map(step_weight).collect();
        ChainAllocator::new(&self.budget, &weights)
    }
}

/// One completed step of a running chain.
#[derive(Debug, Clone)]
pub struct ChainStepResult {
    pub strategy: String,
    /// Strategy chosen by the adaptive router (vs a static baseline).
    pub routed: bool,
    /// Correct *given the step's actual input* (the re-seeded template's
    /// ground truth) — a chain is fully correct iff every step is.
    pub correct: bool,
    pub tokens: usize,
    /// The step's slice ran out mid-strategy.
    pub budget_exhausted: bool,
    /// What the slice granted beyond the step's frozen nominal share.
    pub grant: Grant,
    pub service_ms: f64,
    /// The selected answer, carried into the next step's template.
    pub answer: Option<String>,
}

/// Runtime state of one chain: the spec, its allocator, and the results
/// so far. Pure state transitions — the driver and the blocking runner
/// share them, which is what the stepped-vs-blocking equivalence test
/// pins.
#[derive(Debug)]
pub struct ChainState {
    pub spec: ChainSpec,
    pub alloc: ChainAllocator,
    /// Index of the next step to admit.
    pub next_step: usize,
    /// Previous step's selected answer, reduced to a domain digit.
    carry: Option<i64>,
    pub steps: Vec<ChainStepResult>,
}

impl ChainState {
    pub fn new(spec: ChainSpec) -> ChainState {
        let alloc = spec.allocator();
        ChainState {
            spec,
            alloc,
            next_step: 0,
            carry: None,
            steps: Vec::new(),
        }
    }

    pub fn finished(&self) -> bool {
        self.next_step >= self.spec.steps.len()
    }

    /// True when the chain pool is spent with steps still pending —
    /// the chain must stop and report partial completion.
    pub fn exhausted(&self, elapsed_ms: f64) -> bool {
        !self.finished() && self.alloc.exhausted(elapsed_ms)
    }

    /// The next step's query: its template re-seeded with the carried
    /// answer, with the re-seeded ground truth attached (each step is
    /// judged given its actual input).
    pub fn next_query(&self) -> Query {
        let template = &self.spec.steps[self.next_step];
        let problem = match self.carry {
            Some(v) => template.with_first(v),
            None => template.clone(),
        };
        Query {
            id: format!("{}.s{}", self.spec.id, self.next_step),
            query: problem.query_text(),
            answer: problem.answer().to_string(),
            k: problem.k(),
        }
    }

    /// The next step's budget slice given the chain's elapsed time
    /// (ms since arrival), plus the grant beyond its nominal share.
    pub fn slice(&mut self, elapsed_ms: f64) -> (Budget, Grant) {
        self.alloc.slice(self.next_step, elapsed_ms)
    }

    /// Record a completed step: charge the pool, carry the selected
    /// answer into the next template (a step with no answer carries 0 —
    /// the chain keeps going, it just went wrong).
    pub fn complete_step(&mut self, result: ChainStepResult) {
        self.alloc.charge(result.tokens);
        let digit = result
            .answer
            .as_deref()
            .and_then(|a| a.trim().parse::<i64>().ok())
            .map(|v| v.rem_euclid(MODULUS))
            .unwrap_or(0);
        self.carry = Some(digit);
        self.steps.push(result);
        self.next_step += 1;
    }

    /// Final per-chain record. `exhausted` marks a chain cut short by
    /// its pool (partial steps), as opposed to one that ran them all.
    pub fn into_outcome(self, e2e_ms: f64, exhausted: bool) -> ChainOutcome {
        let steps_total = self.spec.steps.len();
        let all_correct = self.steps.len() == steps_total && self.steps.iter().all(|s| s.correct);
        // the goodput SLO check: no chain deadline means always in SLO
        let under_slo = match self.spec.budget.deadline_ms {
            None => true,
            Some(d) => e2e_ms <= d,
        };
        ChainOutcome {
            id: self.spec.id,
            steps_total,
            all_correct,
            goodput_ok: all_correct && under_slo,
            tokens: self.steps.iter().map(|s| s.tokens).sum(),
            realloc_grants: self.alloc.grants,
            granted_ms: self.alloc.granted_ms,
            granted_tokens: self.alloc.granted_tokens,
            budget_exhausted: exhausted || self.steps.iter().any(|s| s.budget_exhausted),
            e2e_ms,
            deadline_ms: self.spec.budget.deadline_ms,
            steps: self.steps,
        }
    }
}

/// Final record of one chain.
#[derive(Debug, Clone)]
pub struct ChainOutcome {
    pub id: String,
    pub steps_total: usize,
    pub steps: Vec<ChainStepResult>,
    /// Every step ran and was correct given its actual input.
    pub all_correct: bool,
    /// Fully correct AND under the chain SLO — the goodput numerator.
    pub goodput_ok: bool,
    pub tokens: usize,
    /// Slices that exceeded their nominal share (cross-step banking).
    pub realloc_grants: usize,
    pub granted_ms: f64,
    pub granted_tokens: usize,
    /// The chain pool (or a step slice) ran out before the chain's
    /// configured work finished.
    pub budget_exhausted: bool,
    /// Arrival → last step completion, ms.
    pub e2e_ms: f64,
    /// The chain SLO the goodput check compared `e2e_ms` against.
    pub deadline_ms: Option<f64>,
}

impl ChainOutcome {
    pub fn steps_completed(&self) -> usize {
        self.steps.len()
    }
}

// ---------------------------------------------------------------------
// Traffic generation
// ---------------------------------------------------------------------

/// Heavy-tailed session length: a bounded Pareto (α = 1.5) over
/// `[MIN_CHAIN_STEPS, MAX_CHAIN_STEPS]` — most sessions are short, the
/// tail is fat enough that long sessions shape the goodput picture.
pub fn sample_chain_len(rng: &mut Rng) -> usize {
    let u = rng.f64().min(1.0 - 1e-12);
    let len = (MIN_CHAIN_STEPS as f64) / (1.0 - u).powf(1.0 / 1.5);
    (len.floor() as usize).clamp(MIN_CHAIN_STEPS, MAX_CHAIN_STEPS)
}

/// Sample `n` chains: heavy-tailed lengths, steps drawn evenly from
/// both task domains with per-step difficulty `k ∈ [2, 5]`, arrivals
/// from the given process, every chain carrying (a clone of) `budget`.
/// A pure function of the rng seed, like every schedule in
/// [`crate::server::loadgen`].
pub fn sample_chains(
    n: usize,
    budget: &Budget,
    arrivals: Arrivals,
    rng: &mut Rng,
) -> Vec<ChainSpec> {
    let mut t = 0.0f64;
    (0..n)
        .map(|i| {
            t += arrival_gap_s(arrivals, rng, i) * 1e3;
            let len = sample_chain_len(rng);
            let steps = (0..len)
                .map(|_| {
                    let k = rng.range(2, 6) as usize;
                    if rng.below(2) == 0 {
                        ChainProblem::Arith(Problem::sample(rng, k))
                    } else {
                        ChainProblem::Max(MaxProblem::sample(rng, k))
                    }
                })
                .collect();
            ChainSpec {
                id: format!("c{i}"),
                arrival_ms: t,
                budget: budget.clone(),
                steps,
            }
        })
        .collect()
}

// ---------------------------------------------------------------------
// Trace files
// ---------------------------------------------------------------------

/// Trace file format version (see `docs/chains.md` for the golden
/// example).
pub const TRACE_VERSION: usize = 1;

fn expr_of(p: &ChainProblem) -> String {
    let q = p.query_text();
    q.strip_prefix("Q:")
        .and_then(|r| r.strip_suffix("=?\n"))
        .expect("query_text shape")
        .to_string()
}

/// Serialize chains as a deterministic JSON trace:
///
/// ```json
/// {"version":1,"chains":[{"id":"c0","arrival_ms":0.0,
///   "budget":{"deadline_ms":4000.0,"max_tokens":600},
///   "steps":["7+3-5*2","max(0,4,9)"]}]}
/// ```
///
/// Step expressions are the `Q:`/`=?` payload of [`ChainProblem`]
/// (`parse_expr` grammar); `budget` keys are optional (absent =
/// unlimited on that axis).
pub fn emit_trace(chains: &[ChainSpec]) -> Value {
    let arr = chains
        .iter()
        .map(|c| {
            let mut budget = Value::obj();
            if let Some(d) = c.budget.deadline_ms {
                budget.set("deadline_ms", d);
            }
            if let Some(t) = c.budget.max_tokens {
                budget.set("max_tokens", t);
            }
            Value::obj()
                .with("id", c.id.as_str())
                .with("arrival_ms", c.arrival_ms)
                .with("budget", budget)
                .with(
                    "steps",
                    Value::Arr(c.steps.iter().map(|s| Value::Str(expr_of(s))).collect()),
                )
        })
        .collect();
    Value::obj()
        .with("version", TRACE_VERSION)
        .with("chains", Value::Arr(arr))
}

/// Parse a trace file produced by [`emit_trace`] (or written by hand).
/// Strict: unknown versions, empty/invalid step expressions,
/// non-finite/negative arrivals and non-positive budget limits are
/// rejected — replay must be exact or not at all.
pub fn parse_trace(text: &str) -> Result<Vec<ChainSpec>> {
    let v = json::parse(text)?;
    let version = v.req_usize("version")?;
    if version != TRACE_VERSION {
        return Err(Error::Config(format!(
            "trace version {version} unsupported (expected {TRACE_VERSION})"
        )));
    }
    let mut out = Vec::new();
    for (i, c) in v.req_arr("chains")?.iter().enumerate() {
        let id = c.req_str("id")?.to_string();
        let arrival_ms = c.req_f64("arrival_ms")?;
        if !arrival_ms.is_finite() || arrival_ms < 0.0 {
            return Err(Error::Config(format!(
                "trace chain {id}: bad arrival_ms {arrival_ms}"
            )));
        }
        let mut budget = Budget::unlimited();
        if let Some(b) = c.get("budget") {
            if let Some(d) = b.get("deadline_ms").and_then(Value::as_f64) {
                if !d.is_finite() || d <= 0.0 {
                    return Err(Error::Config(format!(
                        "trace chain {id}: deadline_ms must be > 0 (omit for unlimited)"
                    )));
                }
                budget = budget.with_deadline_ms(d);
            }
            if let Some(t) = b.get("max_tokens").and_then(Value::as_usize) {
                if t == 0 {
                    return Err(Error::Config(format!(
                        "trace chain {id}: max_tokens must be > 0 (omit for unlimited)"
                    )));
                }
                budget = budget.with_max_tokens(t);
            }
        }
        let steps_json = c.req_arr("steps")?;
        if steps_json.is_empty() {
            return Err(Error::Config(format!("trace chain {id}: no steps")));
        }
        let mut steps = Vec::with_capacity(steps_json.len());
        for s in steps_json {
            let expr = s
                .as_str()
                .ok_or_else(|| Error::Config(format!("trace chain {id}: step is not a string")))?;
            let p = ChainProblem::parse_expr(expr).ok_or_else(|| {
                Error::Config(format!("trace chain {id}: unparseable step expr '{expr}'"))
            })?;
            steps.push(p);
        }
        // arrivals must be sorted so the driver can admit in order
        let prev_arrival = out.last().map_or(0.0, |p: &ChainSpec| p.arrival_ms);
        if arrival_ms < prev_arrival {
            return Err(Error::Config(format!(
                "trace chain {id} (index {i}): arrivals must be non-decreasing"
            )));
        }
        out.push(ChainSpec {
            id,
            arrival_ms,
            budget,
            steps,
        });
    }
    if out.is_empty() {
        return Err(Error::Config("trace has no chains".into()));
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// Blocking reference runner
// ---------------------------------------------------------------------

/// Run one chain to completion on the blocking path: route each step
/// against its current slice, run it, re-split. The reference the
/// stepped driver is equivalence-tested against (temp 0, SimBackend),
/// and the engine of the static-vs-shared budget comparison: pass
/// `shared_pool = false` to freeze every slice at its nominal share
/// (no cross-step banking) at equal total budget.
pub fn run_chain_blocking(
    executor: &Executor,
    mode: &Mode,
    spec: ChainSpec,
    shared_pool: bool,
) -> Result<ChainOutcome> {
    let t0 = executor.clock.now_ms();
    let mut state = ChainState::new(spec);
    loop {
        let elapsed = executor.clock.now_ms() - t0;
        if state.finished() {
            return Ok(state.into_outcome(elapsed, false));
        }
        if state.exhausted(elapsed) {
            return Ok(state.into_outcome(elapsed, true));
        }
        let (budget, grant) = if shared_pool {
            state.slice(elapsed)
        } else {
            (state.alloc.nominal_budget(state.next_step), Grant::default())
        };
        let query = state.next_query();
        let req = Request {
            query: query.clone(),
            arrival_ms: 0.0,
            seq: state.next_step,
            budget: budget.clone(),
        };
        let (strategy, routed, _predicted) = route(executor, mode, &req)?;
        let s0 = executor.clock.now_ms();
        let outcome = executor.run_budgeted(&strategy, &query.query, budget)?;
        state.complete_step(ChainStepResult {
            strategy: strategy.id(),
            routed,
            correct: outcome.is_correct(&query.answer),
            tokens: outcome.tokens,
            budget_exhausted: outcome.budget_exhausted,
            grant,
            service_ms: executor.clock.now_ms() - s0,
            answer: outcome.answer,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, prop_assert};

    fn spec(id: &str, arrival_ms: f64, budget: Budget, exprs: &[&str]) -> ChainSpec {
        ChainSpec {
            id: id.to_string(),
            arrival_ms,
            budget,
            steps: exprs
                .iter()
                .map(|e| ChainProblem::parse_expr(e).unwrap())
                .collect(),
        }
    }

    #[test]
    fn next_query_reseeds_with_carried_answer() {
        let c = spec(
            "c0",
            0.0,
            Budget::unlimited(),
            &["7+8-5", "max(0,4,9)", "1*2+3"],
        );
        let mut state = ChainState::new(c);
        let q0 = state.next_query();
        assert_eq!(q0.id, "c0.s0");
        assert_eq!(q0.query, "Q:7+8-5=?\n");
        assert_eq!(q0.answer, "0");
        state.complete_step(ChainStepResult {
            strategy: "mv@2".into(),
            routed: false,
            correct: true,
            tokens: 10,
            budget_exhausted: false,
            grant: Grant::default(),
            service_ms: 1.0,
            answer: Some("0".into()),
        });
        // step 1's template first item is replaced by the carry (0)
        let q1 = state.next_query();
        assert_eq!(q1.query, "Q:max(0,4,9)=?\n");
        assert_eq!(q1.answer, "9");
        // a wrong carry changes the next step's ground truth: the chain
        // is judged on what actually flowed, not on the template
        state.complete_step(ChainStepResult {
            strategy: "mv@2".into(),
            routed: false,
            correct: true,
            tokens: 10,
            budget_exhausted: false,
            grant: Grant::default(),
            service_ms: 1.0,
            answer: Some("7".into()),
        });
        let q2 = state.next_query();
        assert_eq!(q2.query, "Q:7*2+3=?\n");
        assert_eq!(q2.answer, "7"); // (7*2+3) mod 10
    }

    #[test]
    fn missing_answer_carries_zero_and_marks_partial() {
        let c = spec("c1", 0.0, Budget::unlimited(), &["7+8-5", "2+2"]);
        let mut state = ChainState::new(c);
        state.complete_step(ChainStepResult {
            strategy: "mv@2".into(),
            routed: false,
            correct: false,
            tokens: 4,
            budget_exhausted: true,
            grant: Grant::default(),
            service_ms: 1.0,
            answer: None,
        });
        assert_eq!(state.next_query().query, "Q:0+2=?\n");
        let out = state.into_outcome(50.0, true);
        assert_eq!(out.steps_completed(), 1);
        assert!(!out.all_correct);
        assert!(out.budget_exhausted);
        assert!(!out.goodput_ok);
    }

    #[test]
    fn goodput_requires_correct_and_under_slo() {
        let full = |e2e_ms: f64, deadline: Option<f64>| {
            let mut budget = Budget::unlimited();
            if let Some(d) = deadline {
                budget = budget.with_deadline_ms(d);
            }
            let mut state = ChainState::new(spec("c2", 0.0, budget, &["7+8-5"]));
            state.complete_step(ChainStepResult {
                strategy: "mv@2".into(),
                routed: false,
                correct: true,
                tokens: 4,
                budget_exhausted: false,
                grant: Grant::default(),
                service_ms: 1.0,
                answer: Some("0".into()),
            });
            state.into_outcome(e2e_ms, false)
        };
        assert!(full(100.0, None).goodput_ok);
        assert!(full(100.0, Some(200.0)).goodput_ok);
        assert!(!full(300.0, Some(200.0)).goodput_ok, "over SLO");
    }

    #[test]
    fn chain_exhaustion_is_detected_before_admission() {
        let c = spec(
            "c3",
            0.0,
            Budget::unlimited().with_deadline_ms(100.0),
            &["7+8-5", "2+2"],
        );
        let state = ChainState::new(c);
        assert!(!state.exhausted(50.0));
        assert!(state.exhausted(150.0));
    }

    #[test]
    fn sampled_chains_are_deterministic_and_bounded() {
        let sample = |seed| {
            let mut rng = Rng::new(seed, 0);
            sample_chains(
                30,
                &Budget::unlimited().with_deadline_ms(4000.0),
                Arrivals::Poisson { rate: 5.0 },
                &mut rng,
            )
        };
        let a = sample(9);
        assert_eq!(a, sample(9), "same seed must reproduce exactly");
        assert_ne!(a, sample(10), "different seeds should differ");
        for c in &a {
            assert!((MIN_CHAIN_STEPS..=MAX_CHAIN_STEPS).contains(&c.steps.len()));
            assert_eq!(c.budget.deadline_ms, Some(4000.0));
        }
        assert!(
            a.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms),
            "arrivals sorted"
        );
        // both domains appear across 30 heterogeneous chains
        let domains: Vec<&str> = a
            .iter()
            .flat_map(|c| c.steps.iter().map(|s| s.domain()))
            .collect();
        assert!(domains.contains(&"arith") && domains.contains(&"max"));
    }

    #[test]
    fn trace_golden_example_parses() {
        let text = r#"{"version":1,"chains":[
            {"id":"c0","arrival_ms":0.0,
             "budget":{"deadline_ms":4000.0,"max_tokens":600},
             "steps":["7+3-5*2","max(0,4,9)"]},
            {"id":"c1","arrival_ms":120.5,"steps":["1+2"]}]}"#;
        let chains = parse_trace(text).unwrap();
        assert_eq!(chains.len(), 2);
        assert_eq!(chains[0].id, "c0");
        assert_eq!(chains[0].budget.deadline_ms, Some(4000.0));
        assert_eq!(chains[0].budget.max_tokens, Some(600));
        assert_eq!(chains[0].steps[1].domain(), "max");
        assert!(chains[1].budget.is_unlimited());
    }

    #[test]
    fn trace_rejects_malformed() {
        for bad in [
            "{}",
            r#"{"version":2,"chains":[]}"#,
            r#"{"version":1,"chains":[]}"#,
            r#"{"version":1,"chains":[{"id":"c","arrival_ms":0.0,"steps":[]}]}"#,
            r#"{"version":1,"chains":[{"id":"c","arrival_ms":0.0,"steps":["7/2"]}]}"#,
            r#"{"version":1,"chains":[{"id":"c","arrival_ms":-1.0,"steps":["1+2"]}]}"#,
            r#"{"version":1,"chains":[{"id":"c","arrival_ms":0.0,
                "budget":{"deadline_ms":0.0},"steps":["1+2"]}]}"#,
            r#"{"version":1,"chains":[{"id":"c","arrival_ms":0.0,
                "budget":{"max_tokens":0},"steps":["1+2"]}]}"#,
            r#"{"version":1,"chains":[
                {"id":"a","arrival_ms":5.0,"steps":["1+2"]},
                {"id":"b","arrival_ms":1.0,"steps":["1+2"]}]}"#,
        ] {
            assert!(parse_trace(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn prop_trace_roundtrips_sampled_chains() {
        forall(
            "emit_trace ∘ parse_trace is identity",
            50,
            |rng| {
                let n = rng.range(1, 8) as usize;
                let budget = match rng.below(4) {
                    0 => Budget::unlimited(),
                    1 => Budget::unlimited().with_deadline_ms(1.0 + rng.f64() * 5000.0),
                    2 => Budget::unlimited().with_max_tokens(1 + rng.below(1000) as usize),
                    _ => Budget::unlimited()
                        .with_deadline_ms(1.0 + rng.f64() * 5000.0)
                        .with_max_tokens(1 + rng.below(1000) as usize),
                };
                let mut rng2 = rng.split();
                sample_chains(n, &budget, Arrivals::Poisson { rate: 20.0 }, &mut rng2)
            },
            |chains| {
                let text = emit_trace(chains).dumps();
                let back = parse_trace(&text).map_err(|e| format!("parse failed: {e}"))?;
                prop_assert(back == *chains, "trace roundtrip mismatch".to_string())?;
                Ok(())
            },
        );
    }
}
