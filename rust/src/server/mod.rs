//! Serving driver, load generator and CLI command implementations.

pub mod commands;
pub mod driver;
pub mod loadgen;
