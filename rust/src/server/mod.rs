//! Serving driver, load generator, agentic chain tier and CLI command
//! implementations.

pub mod chain;
pub mod commands;
pub mod driver;
pub mod loadgen;
