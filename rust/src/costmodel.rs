//! Cost models `T̂_s(x)`, `L̂_s(x)` (paper §2.4), budget-aware.
//!
//! Following the paper, unbudgeted predicted costs are **per-strategy
//! training-set means** — "cost variation is dominated by the choice of
//! strategy rather than the query" (validated by our Figs 7/8
//! reproduction, where mean-cost routing tracks oracle-cost routing
//! closely).
//!
//! Under a per-request deadline the realized cost is *truncated*: the
//! engine preempts decoding mid-call and the beam family stops issuing
//! rounds. The model therefore also fits a per-(strategy,
//! deadline-bucket) table from the same matrix by predicting what each
//! recorded run would have cost under that bucket's deadline:
//!
//! * round-based strategies (beam family): predict **rounds completed**
//!   — `⌊d / per_round_ms⌋` rounds at the run's mean per-round cost;
//! * single-batch parallel strategies: mid-call preemption prorates the
//!   call linearly — `min(latency, d)` and the matching token fraction.
//!
//! [`CostModel::get_budgeted`] resolves a request deadline to the
//! smallest bucket that covers it (conservative: never predicts more
//! truncation than the deadline allows), so the router's feasibility
//! check — predicted latency ≤ deadline — excludes exactly the
//! strategies whose truncated work still would not fit.

use crate::error::{Error, Result};
use crate::matrix::Matrix;
use crate::strategies::Strategy;
use crate::util::json::Value;
use crate::util::stats;
use std::collections::HashMap;

/// Deadline-bucket upper edges (ms) used by [`CostModel::fit`]. An
/// implicit unbounded bucket (the unbudgeted means) follows the last
/// edge.
pub const DEFAULT_DEADLINE_BUCKETS: &[f64] = &[250.0, 500.0, 1000.0, 2000.0, 4000.0, 8000.0];

/// Predicted cost of one strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostEstimate {
    pub tokens: f64,
    pub latency_ms: f64,
}

/// Per-strategy cost tables fitted on the train-split matrix: unbudgeted
/// means plus truncated per-deadline-bucket estimates.
#[derive(Debug, Clone, Default)]
pub struct CostModel {
    table: HashMap<String, CostEstimate>,
    /// Ascending deadline-bucket upper edges (ms).
    buckets: Vec<f64>,
    /// Strategy id → per-bucket truncated estimates (parallel to
    /// `buckets`).
    bucketed: HashMap<String, Vec<CostEstimate>>,
}

/// `(tokens, latency_ms, rounds)` of one recorded run.
type RunCost = (f64, f64, usize);

/// Predict one recorded run's cost under deadline `d`. `rounds` is the
/// run's completed generation rounds; `uses_rounds` selects the
/// rounds-completed model over linear proration.
fn truncate_run(
    tokens: f64,
    latency_ms: f64,
    rounds: usize,
    uses_rounds: bool,
    d: f64,
) -> CostEstimate {
    if latency_ms <= d {
        return CostEstimate { tokens, latency_ms };
    }
    if uses_rounds && rounds > 0 {
        let per_round_ms = latency_ms / rounds as f64;
        let per_round_tokens = tokens / rounds as f64;
        let rounds_done = ((d / per_round_ms).floor() as usize).min(rounds);
        CostEstimate {
            tokens: per_round_tokens * rounds_done as f64,
            latency_ms: per_round_ms * rounds_done as f64,
        }
    } else {
        let frac = (d / latency_ms.max(1e-9)).clamp(0.0, 1.0);
        CostEstimate {
            tokens: tokens * frac,
            latency_ms: latency_ms.min(d),
        }
    }
}

impl CostModel {
    /// Fit means + default deadline buckets from a (train-split) matrix.
    pub fn fit(matrix: &Matrix) -> CostModel {
        CostModel::fit_with_buckets(matrix, DEFAULT_DEADLINE_BUCKETS)
    }

    /// Fit with explicit deadline-bucket edges (ascending, ms).
    pub fn fit_with_buckets(matrix: &Matrix, buckets: &[f64]) -> CostModel {
        let mut groups: HashMap<String, Vec<RunCost>> = HashMap::new();
        for e in &matrix.entries {
            groups
                .entry(e.strategy.clone())
                .or_default()
                .push((e.tokens as f64, e.latency_ms, e.rounds.max(1)));
        }
        let mean_est = |costs: &[CostEstimate]| CostEstimate {
            tokens: stats::mean(&costs.iter().map(|c| c.tokens).collect::<Vec<_>>()),
            latency_ms: stats::mean(&costs.iter().map(|c| c.latency_ms).collect::<Vec<_>>()),
        };
        let mut table = HashMap::new();
        let mut bucketed = HashMap::new();
        for (s, runs) in groups {
            let uses_rounds = Strategy::parse(&s).is_some_and(|st| st.uses_rounds());
            let per_bucket: Vec<CostEstimate> = buckets
                .iter()
                .map(|&d| {
                    let cut: Vec<CostEstimate> = runs
                        .iter()
                        .map(|&(t, l, r)| truncate_run(t, l, r, uses_rounds, d))
                        .collect();
                    mean_est(&cut)
                })
                .collect();
            let full: Vec<CostEstimate> = runs
                .iter()
                .map(|&(t, l, _)| CostEstimate {
                    tokens: t,
                    latency_ms: l,
                })
                .collect();
            table.insert(s.clone(), mean_est(&full));
            bucketed.insert(s, per_bucket);
        }
        CostModel {
            table,
            buckets: buckets.to_vec(),
            bucketed,
        }
    }

    /// Unbudgeted per-strategy mean (the paper's `T̂`, `L̂`).
    pub fn get(&self, strategy_id: &str) -> Result<CostEstimate> {
        self.table.get(strategy_id).copied().ok_or_else(|| {
            Error::internal(format!("no cost estimate for strategy '{strategy_id}'"))
        })
    }

    /// Predicted cost under an optional request deadline: the truncated
    /// estimate of the smallest bucket covering `deadline_ms`, or the
    /// unbudgeted mean when there is no deadline / no bucket covers it /
    /// the model was loaded from a pre-bucket checkpoint.
    pub fn get_budgeted(
        &self,
        strategy_id: &str,
        deadline_ms: Option<f64>,
    ) -> Result<CostEstimate> {
        let unbudgeted = self.get(strategy_id)?;
        let Some(d) = deadline_ms else {
            return Ok(unbudgeted);
        };
        let Some(ix) = self.buckets.iter().position(|&edge| edge >= d) else {
            return Ok(unbudgeted);
        };
        Ok(self
            .bucketed
            .get(strategy_id)
            .and_then(|v| v.get(ix))
            .copied()
            .unwrap_or(unbudgeted))
    }

    /// Bucket edges this model was fitted with (empty for legacy models).
    pub fn bucket_edges(&self) -> &[f64] {
        &self.buckets
    }

    pub fn len(&self) -> usize {
        self.table.len()
    }

    pub fn is_empty(&self) -> bool {
        self.table.is_empty()
    }

    pub fn to_json(&self) -> Value {
        let mut strategies = Value::obj();
        let mut ids: Vec<&String> = self.table.keys().collect();
        ids.sort();
        for id in ids {
            let c = self.table[id];
            let mut entry = Value::obj()
                .with("tokens", c.tokens)
                .with("latency_ms", c.latency_ms);
            if let Some(per_bucket) = self.bucketed.get(id.as_str()) {
                let arr: Vec<Value> = per_bucket
                    .iter()
                    .map(|b| {
                        Value::obj()
                            .with("tokens", b.tokens)
                            .with("latency_ms", b.latency_ms)
                    })
                    .collect();
                entry.set("by_bucket", Value::Arr(arr));
            }
            strategies.set(id, entry);
        }
        Value::obj()
            .with("buckets", self.buckets.clone())
            .with("strategies", strategies)
    }

    pub fn from_json(v: &Value) -> Result<CostModel> {
        // New format: {buckets: [...], strategies: {id: {..., by_bucket}}}.
        // Legacy format (pre-bucket): {id: {tokens, latency_ms}, ...}.
        let (buckets, strat_obj) = match (v.get("buckets"), v.get("strategies")) {
            (Some(b), Some(s)) => {
                let edges: Vec<f64> = b
                    .as_arr()
                    .ok_or_else(|| Error::Json("buckets must be an array".into()))?
                    .iter()
                    .map(|e| e.as_f64().ok_or_else(|| Error::Json("bad bucket edge".into())))
                    .collect::<Result<_>>()?;
                (edges, s)
            }
            _ => (Vec::new(), v),
        };
        let mut table = HashMap::new();
        let mut bucketed = HashMap::new();
        for (k, c) in strat_obj
            .as_obj()
            .ok_or_else(|| Error::Json("cost model must be an object".into()))?
        {
            table.insert(
                k.clone(),
                CostEstimate {
                    tokens: c.req_f64("tokens")?,
                    latency_ms: c.req_f64("latency_ms")?,
                },
            );
            if let Some(arr) = c.get("by_bucket").and_then(Value::as_arr) {
                let per_bucket: Vec<CostEstimate> = arr
                    .iter()
                    .map(|b| {
                        Ok(CostEstimate {
                            tokens: b.req_f64("tokens")?,
                            latency_ms: b.req_f64("latency_ms")?,
                        })
                    })
                    .collect::<Result<_>>()?;
                if per_bucket.len() != buckets.len() {
                    return Err(Error::Json(format!(
                        "strategy '{k}' has {} bucket estimates for {} buckets",
                        per_bucket.len(),
                        buckets.len()
                    )));
                }
                bucketed.insert(k.clone(), per_bucket);
            }
        }
        Ok(CostModel {
            table,
            buckets,
            bucketed,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::MatrixEntry;

    fn entry(q: &str, s: &str, tokens: usize, latency_ms: f64, rounds: usize) -> MatrixEntry {
        MatrixEntry {
            query_id: q.into(),
            split: "train".into(),
            strategy: s.into(),
            repeat: 0,
            k: 2,
            correct: true,
            tokens,
            latency_ms,
            rounds,
        }
    }

    fn m() -> Matrix {
        Matrix {
            entries: vec![
                entry("a", "majority_vote@4", 100, 50.0, 1),
                entry("b", "majority_vote@4", 200, 150.0, 1),
                entry("a", "beam@4x2c12", 900, 2000.0, 10),
            ],
        }
    }

    #[test]
    fn fit_means() {
        let cm = CostModel::fit(&m());
        let c = cm.get("majority_vote@4").unwrap();
        assert_eq!(c.tokens, 150.0);
        assert_eq!(c.latency_ms, 100.0);
        assert_eq!(cm.get("beam@4x2c12").unwrap().tokens, 900.0);
        assert!(cm.get("unknown@1").is_err());
    }

    #[test]
    fn json_roundtrip_with_buckets() {
        let cm = CostModel::fit(&m());
        let back = CostModel::from_json(&cm.to_json()).unwrap();
        assert_eq!(
            back.get("majority_vote@4").unwrap(),
            cm.get("majority_vote@4").unwrap()
        );
        assert_eq!(back.len(), cm.len());
        assert_eq!(back.bucket_edges(), cm.bucket_edges());
        for &d in DEFAULT_DEADLINE_BUCKETS {
            assert_eq!(
                back.get_budgeted("beam@4x2c12", Some(d)).unwrap(),
                cm.get_budgeted("beam@4x2c12", Some(d)).unwrap()
            );
        }
    }

    #[test]
    fn legacy_flat_json_still_loads() {
        let legacy = crate::util::json::parse(
            r#"{"mv@4": {"tokens": 120.0, "latency_ms": 60.0}}"#,
        )
        .unwrap();
        let cm = CostModel::from_json(&legacy).unwrap();
        assert_eq!(cm.get("mv@4").unwrap().tokens, 120.0);
        // no buckets: budgeted lookups fall back to the flat mean
        let c = cm.get_budgeted("mv@4", Some(10.0)).unwrap();
        assert_eq!(c.latency_ms, 60.0);
    }

    #[test]
    fn rounds_truncation_for_beam_family() {
        let cm = CostModel::fit(&m());
        // beam: 2000ms over 10 rounds = 200ms/round, 90 tokens/round.
        // A 1000ms bucket fits 5 rounds.
        let c = cm.get_budgeted("beam@4x2c12", Some(1000.0)).unwrap();
        assert!((c.latency_ms - 1000.0).abs() < 1e-9);
        assert!((c.tokens - 450.0).abs() < 1e-9);
        // and the truncated estimate respects the bucket edge
        for &d in DEFAULT_DEADLINE_BUCKETS {
            let c = cm.get_budgeted("beam@4x2c12", Some(d)).unwrap();
            assert!(
                c.latency_ms <= d + 1e-9,
                "bucket {d}: {} exceeds edge",
                c.latency_ms
            );
        }
    }

    #[test]
    fn proration_for_parallel_methods() {
        let cm = CostModel::fit(&m());
        // mv runs: (100 tok, 50ms) fits a 100ms deadline whole;
        // (200 tok, 150ms) prorates to 2/3 → 133.3 tok, 100ms.
        let c = cm.get_budgeted("majority_vote@4", Some(100.0)).unwrap();
        assert!((c.latency_ms - 75.0).abs() < 1e-9); // mean(50, 100)
        let expected_tokens = (100.0 + 200.0 * (100.0 / 150.0)) / 2.0;
        assert!((c.tokens - expected_tokens).abs() < 1e-9);
    }

    #[test]
    fn deadline_beyond_buckets_is_unbudgeted() {
        let cm = CostModel::fit(&m());
        assert_eq!(
            cm.get_budgeted("beam@4x2c12", Some(1e9)).unwrap(),
            cm.get("beam@4x2c12").unwrap()
        );
        assert_eq!(
            cm.get_budgeted("beam@4x2c12", None).unwrap(),
            cm.get("beam@4x2c12").unwrap()
        );
    }

    #[test]
    fn truncate_run_edge_cases() {
        // run faster than the deadline: unchanged
        let c = truncate_run(100.0, 50.0, 1, false, 200.0);
        assert_eq!(c, CostEstimate { tokens: 100.0, latency_ms: 50.0 });
        // rounds model: deadline shorter than one round → zero work
        let c = truncate_run(900.0, 2000.0, 10, true, 100.0);
        assert_eq!(c.latency_ms, 0.0);
        assert_eq!(c.tokens, 0.0);
        // proration at half the latency
        let c = truncate_run(100.0, 200.0, 1, false, 100.0);
        assert!((c.tokens - 50.0).abs() < 1e-9);
        assert!((c.latency_ms - 100.0).abs() < 1e-9);
    }
}
