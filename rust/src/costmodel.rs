//! Cost models `T̂_s(x)`, `L̂_s(x)` (paper §2.4).
//!
//! Following the paper, predicted costs are **per-strategy training-set
//! means** — "cost variation is dominated by the choice of strategy
//! rather than the query" (validated by our Figs 7/8 reproduction, where
//! mean-cost routing tracks oracle-cost routing closely).

use crate::error::{Error, Result};
use crate::matrix::Matrix;
use crate::util::json::Value;
use crate::util::stats;
use std::collections::HashMap;

/// Predicted cost of one strategy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostEstimate {
    pub tokens: f64,
    pub latency_ms: f64,
}

/// Per-strategy mean cost table fitted on the train-split matrix.
#[derive(Debug, Clone, Default)]
pub struct CostModel {
    table: HashMap<String, CostEstimate>,
}

impl CostModel {
    /// Fit means from a (train-split) matrix.
    pub fn fit(matrix: &Matrix) -> CostModel {
        let mut groups: HashMap<String, (Vec<f64>, Vec<f64>)> = HashMap::new();
        for e in &matrix.entries {
            let g = groups.entry(e.strategy.clone()).or_default();
            g.0.push(e.tokens as f64);
            g.1.push(e.latency_ms);
        }
        CostModel {
            table: groups
                .into_iter()
                .map(|(s, (toks, lats))| {
                    (
                        s,
                        CostEstimate {
                            tokens: stats::mean(&toks),
                            latency_ms: stats::mean(&lats),
                        },
                    )
                })
                .collect(),
        }
    }

    pub fn get(&self, strategy_id: &str) -> Result<CostEstimate> {
        self.table.get(strategy_id).copied().ok_or_else(|| {
            Error::internal(format!("no cost estimate for strategy '{strategy_id}'"))
        })
    }

    pub fn len(&self) -> usize {
        self.table.len()
    }

    pub fn is_empty(&self) -> bool {
        self.table.is_empty()
    }

    pub fn to_json(&self) -> Value {
        let mut obj = Value::obj();
        let mut ids: Vec<&String> = self.table.keys().collect();
        ids.sort();
        for id in ids {
            let c = self.table[id];
            obj.set(
                id,
                Value::obj()
                    .with("tokens", c.tokens)
                    .with("latency_ms", c.latency_ms),
            );
        }
        obj
    }

    pub fn from_json(v: &Value) -> Result<CostModel> {
        let mut table = HashMap::new();
        for (k, c) in v
            .as_obj()
            .ok_or_else(|| Error::Json("cost model must be an object".into()))?
        {
            table.insert(
                k.clone(),
                CostEstimate {
                    tokens: c.req_f64("tokens")?,
                    latency_ms: c.req_f64("latency_ms")?,
                },
            );
        }
        Ok(CostModel { table })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::MatrixEntry;

    fn m() -> Matrix {
        Matrix {
            entries: vec![
                MatrixEntry {
                    query_id: "a".into(),
                    split: "train".into(),
                    strategy: "mv@4".into(),
                    repeat: 0,
                    k: 2,
                    correct: true,
                    tokens: 100,
                    latency_ms: 50.0,
                },
                MatrixEntry {
                    query_id: "b".into(),
                    split: "train".into(),
                    strategy: "mv@4".into(),
                    repeat: 0,
                    k: 5,
                    correct: false,
                    tokens: 200,
                    latency_ms: 150.0,
                },
                MatrixEntry {
                    query_id: "a".into(),
                    split: "train".into(),
                    strategy: "beam@4x2c12".into(),
                    repeat: 0,
                    k: 2,
                    correct: true,
                    tokens: 900,
                    latency_ms: 2000.0,
                },
            ],
        }
    }

    #[test]
    fn fit_means() {
        let cm = CostModel::fit(&m());
        let c = cm.get("mv@4").unwrap();
        assert_eq!(c.tokens, 150.0);
        assert_eq!(c.latency_ms, 100.0);
        assert_eq!(cm.get("beam@4x2c12").unwrap().tokens, 900.0);
        assert!(cm.get("unknown@1").is_err());
    }

    #[test]
    fn json_roundtrip() {
        let cm = CostModel::fit(&m());
        let back = CostModel::from_json(&cm.to_json()).unwrap();
        assert_eq!(back.get("mv@4").unwrap(), cm.get("mv@4").unwrap());
        assert_eq!(back.len(), cm.len());
    }
}
