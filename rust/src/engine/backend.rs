//! The pluggable execution-backend API.
//!
//! The engine thread ([`crate::engine::thread`]) owns scheduling,
//! coalescing, budget preemption and metrics — none of which care *what*
//! executes a bucket-shaped call. That part is the [`Backend`] trait:
//! one bucket-shaped `generate` / `prm_score` / `embed` (plus the probe
//! ops and shape/identity metadata), implemented by
//!
//! * [`crate::engine::thread::DeviceBackend`] — the PJRT device path
//!   (AOT'd executables, device-resident weights); and
//! * [`SimBackend`] (below) — a deterministic model-free emulator of the
//!   trained LM/PRM over the synthetic task domains. It needs no
//!   artifacts, so every serve / stepper / pool / bench path can run
//!   engine-full on a fresh checkout, with latencies supplied by the
//!   calibrated [`crate::util::clock::SimClock`] cost model.
//!
//! The contract (shared by every backend, enforced by the engine thread
//! where possible — see `docs/backends.md`):
//!
//! * calls are **bucket-shaped**: the engine thread plans real rows into
//!   the backend's advertised `shapes()` buckets and never passes more
//!   rows than the bucket holds;
//! * `generate` returns each row's *naturally* generated tokens — the
//!   decode-accounting loop in the engine thread cuts them down to
//!   budget afterwards, identically for every backend;
//! * at temperature 0, `generate` must be a pure function of the prompt
//!   tokens (batch-shape invariant) — this is what makes
//!   stepped == blocking and serial == pool equivalences hold;
//! * `prm_score` / `embed` must be pure functions of their inputs.
//!
//! Backends may additionally implement the **steppable session API**
//! (`prefill` → [`DecodeSession`] → `decode_step`): the engine thread's
//! continuous-batching path drives it iteration-by-iteration, retiring
//! finished/expired rows between steps and admitting newly-arrived jobs
//! into freed slots. A provided run-to-completion adapter (the default
//! method bodies) makes every legacy backend steppable by buffering one
//! `generate` call — correct but saving no real compute — so only
//! backends whose `stepping()` returns `true` are routed through the
//! continuous path. At temperature 0 the stepped output must be
//! byte-identical to `generate`'s for the same prompt.

use crate::config::EngineConfig;
use crate::engine::batcher::BatchPlan;
use crate::engine::protocol::{EmbedKind, GenKind, ProbeTrainReport};
use crate::error::{Error, Result};
use crate::taskgen::ChainProblem;
use crate::tokenizer::Tokenizer;
use crate::util::clock::{CostEvent, SharedClock};
use crate::util::json::Value;
use crate::util::rng::Rng;

/// Static shape info a backend advertises: batch buckets, padded
/// lengths, decode caps and probe dimensions. For the device backend it
/// comes from `hlo_index.json`; the sim backend derives it from the
/// engine config ([`EngineShapes::sim_default`]).
#[derive(Debug, Clone)]
pub struct EngineShapes {
    pub batch_buckets: Vec<usize>,
    pub chunk_lens: Vec<usize>,
    pub query_len: usize,
    pub prm_len: usize,
    pub gen_max_new: usize,
    pub chunk_max_new: usize,
    pub probe_fwd_batch: usize,
    pub probe_train_batch: usize,
    pub probe_features: usize,
    pub d_model: usize,
}

/// d_model of the compiled generator (python/compile/model.py
/// `LM_CONFIG`); the sim backend mirrors it so probe features line up.
const SIM_D_MODEL: usize = 96;

impl EngineShapes {
    pub fn from_meta(meta: &Value) -> Result<EngineShapes> {
        let probe = meta.req("probe")?;
        let lm = meta.req("lm")?;
        Ok(EngineShapes {
            batch_buckets: meta
                .req_arr("batch_buckets")?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| Error::artifact("bad bucket")))
                .collect::<Result<_>>()?,
            chunk_lens: meta
                .req_arr("chunk_lens")?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| Error::artifact("bad len")))
                .collect::<Result<_>>()?,
            query_len: meta.req_usize("query_len")?,
            prm_len: meta.req_usize("prm_len")?,
            gen_max_new: meta.req_usize("gen_max_new")?,
            chunk_max_new: meta.req_usize("chunk_max_new")?,
            probe_fwd_batch: meta.req_usize("probe_fwd_batch")?,
            probe_train_batch: meta.req_usize("probe_train_batch")?,
            probe_features: probe.req_usize("features")?,
            d_model: lm.req_usize("d_model")?,
        })
    }

    /// Shapes for the artifact-free sim backend, mirroring the buckets
    /// `python/compile/aot.py` lowers for the device path. The probe
    /// width is registry-driven so the feature layout matches what
    /// [`crate::probe::FeatureBuilder`] builds today.
    pub fn sim_default(cfg: &EngineConfig) -> EngineShapes {
        EngineShapes {
            batch_buckets: cfg.buckets.clone(),
            chunk_lens: vec![32, 64, 96, 128],
            query_len: cfg.prefill_len,
            prm_len: cfg.prm_len,
            gen_max_new: cfg.max_new_tokens,
            chunk_max_new: 16,
            probe_fwd_batch: 32,
            probe_train_batch: 64,
            probe_features: SIM_D_MODEL + crate::probe::FeatureBuilder::aux_dim(),
            d_model: SIM_D_MODEL,
        }
    }
}

/// One live row's output for a single decode step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepTok {
    /// The token generated this step.
    pub token: u32,
    /// This was the row's *last* natural token. The natural end rides
    /// along *with* the final token (rather than being discovered by an
    /// empty follow-up step) so the engine never charges a decode step
    /// that produced nothing.
    pub last: bool,
}

/// Per-slot output of one decode step, indexed by slot (`0..bucket`):
/// `None` for slots that produced nothing (free or retired), `Some` for
/// each live row's next token.
pub type StepRows = Vec<Option<StepTok>>;

/// A live decode session over one bucket-shaped call. The engine thread
/// owns the scheduling view — which request each slot serves, per-row
/// budgets, emitted prefixes — while the backend parks its execution
/// state (buffers, cursors, device handles) behind the type-erased
/// `state` box.
pub struct DecodeSession {
    pub kind: GenKind,
    pub temperature: f32,
    /// Slot count (the planned batch bucket).
    pub bucket: usize,
    pub len_bucket: usize,
    /// Initial slots whose natural output was already empty at prefill.
    /// The engine retires them before charging any decode step,
    /// mirroring the legacy accounting loop where a zero-length row
    /// never keeps a call alive.
    pub empty_rows: Vec<usize>,
    state: Box<dyn std::any::Any>,
}

impl DecodeSession {
    /// A session shaped like `plan` holding backend-specific `state`.
    pub fn new(plan: &BatchPlan, state: Box<dyn std::any::Any>) -> DecodeSession {
        DecodeSession {
            kind: plan.kind,
            temperature: plan.temperature,
            bucket: plan.bucket,
            len_bucket: plan.len_bucket,
            empty_rows: Vec::new(),
            state,
        }
    }

    /// The backend's parked state, downcast back to its concrete type.
    /// Errs if the session was prefilled by a different backend.
    pub fn state_mut<T: 'static>(&mut self) -> Result<&mut T> {
        self.state.downcast_mut::<T>().ok_or_else(|| {
            Error::Engine(
                "decode session state does not belong to this backend".into(),
            )
        })
    }
}

/// One buffered row of a decode session: the precomputed natural tokens
/// plus the replay cursor. Shared by the run-to-completion adapter and
/// the sim backend's native stepping.
struct BufferedRow {
    natural: Vec<u32>,
    cursor: usize,
}

impl BufferedRow {
    fn step(&mut self) -> Option<StepTok> {
        if self.cursor >= self.natural.len() {
            return None;
        }
        let token = self.natural[self.cursor];
        self.cursor += 1;
        Some(StepTok {
            token,
            last: self.cursor == self.natural.len(),
        })
    }
}

/// Session state of the default run-to-completion adapter: the full
/// `generate` output buffered per slot, replayed one token per step.
struct BufferedSession {
    rows: Vec<Option<BufferedRow>>,
}

/// One bucket-shaped execution surface. Implementations live on the
/// engine thread (they may hold `!Send` state, e.g. PJRT handles); the
/// factory that *builds* them crosses the thread boundary instead
/// ([`BackendFactory`]).
pub trait Backend {
    /// Short stable name for logs and `info()` (`"device"` / `"sim"`).
    fn name(&self) -> &'static str;

    /// Shape metadata the batcher plans against.
    fn shapes(&self) -> &EngineShapes;

    /// Identity/diagnostic metadata merged into the engine's `info()`
    /// (must be a JSON object; the engine thread adds `metrics` and
    /// `shapes` on top).
    fn describe(&self) -> Value;

    /// Advisory: the earliest absolute engine-clock deadline among the
    /// rows of the *next* `generate` call (infinite = none). Local
    /// backends ignore it — the engine thread's accounting loop already
    /// enforces deadlines. [`crate::net::RemoteBackend`] forwards it
    /// (as a relative span) so the server's fleet can preempt too,
    /// instead of generating tokens the client will discard.
    fn deadline_hint(&mut self, _deadline_ms: f64) {}

    /// Execute one bucket-shaped generation call. `prompts[i]` is the
    /// prompt of `plan.job_indices[i]` (already validated against
    /// `plan.len_bucket` by the engine thread). Returns each real row's
    /// naturally generated tokens, bounded by the executable's own
    /// decode cap (`gen_max_new` / `chunk_max_new`); budget cuts happen
    /// in the engine thread's accounting loop afterwards.
    fn generate(&mut self, plan: &BatchPlan, prompts: &[&[u32]]) -> Result<Vec<Vec<u32>>>;

    /// Score up to `bucket` CoT prefixes; one score per prefix.
    /// Prefixes may exceed `shapes().prm_len` — the backend must score
    /// an over-long prefix on its first `prm_len` tokens (both built-in
    /// backends do).
    fn prm_score(&mut self, bucket: usize, prefixes: &[Vec<u32>]) -> Result<Vec<f32>>;

    /// Embed up to `bucket` queries; one `d_model` vector per query.
    fn embed(&mut self, kind: EmbedKind, bucket: usize, queries: &[Vec<u32>]) -> Result<Vec<Vec<f32>>>;

    /// Probe forward (logits) with the backend's current probe params.
    /// Unlike generate/prm/embed (whose clock costs the engine thread
    /// charges), probe ops chunk internally and must charge their own
    /// [`CostEvent::Probe`] per chunk.
    fn probe_fwd(&mut self, feats: &[Vec<f32>]) -> Result<Vec<f32>>;

    /// Train the probe; the backend keeps (and returns) the best params.
    #[allow(clippy::too_many_arguments)]
    fn probe_train(
        &mut self,
        train_feats: &[Vec<f32>],
        train_labels: &[f32],
        val_feats: &[Vec<f32>],
        val_labels: &[f32],
        epochs: usize,
        patience: usize,
    ) -> Result<ProbeTrainReport>;

    /// Replace the backend's probe parameters (e.g. from a checkpoint).
    fn probe_load(&mut self, params: Vec<f32>) -> Result<()>;

    // -- steppable decode sessions (iteration-level scheduling) -------

    /// Whether the steppable API below is implemented *natively* —
    /// i.e. retiring a row between steps genuinely skips its remaining
    /// decode work. The default method bodies are a run-to-completion
    /// adapter over `generate`: correct (so callers never branch) but
    /// compute is already spent by prefill time, so the engine thread
    /// only routes generates through the continuous-batching path when
    /// this returns `true`.
    fn stepping(&self) -> bool {
        false
    }

    /// Open a decode session for one bucket-shaped plan, admitting the
    /// initial rows (`prompts[i]` occupies slot `i`; slots
    /// `prompts.len()..bucket` start free). Slots whose natural output
    /// is already empty are listed in the session's `empty_rows`.
    fn prefill(&mut self, plan: &BatchPlan, prompts: &[&[u32]]) -> Result<DecodeSession> {
        let naturals = self.generate(plan, prompts)?;
        let mut rows: Vec<Option<BufferedRow>> = (0..plan.bucket).map(|_| None).collect();
        let mut empty = Vec::new();
        for (slot, natural) in naturals.into_iter().enumerate() {
            if natural.is_empty() {
                empty.push(slot);
            }
            rows[slot] = Some(BufferedRow { natural, cursor: 0 });
        }
        let mut session = DecodeSession::new(plan, Box::new(BufferedSession { rows }));
        session.empty_rows = empty;
        Ok(session)
    }

    /// Advance every live row by one token. A `None` on a slot the
    /// caller believes occupied means the row has nothing further
    /// (already past its natural end) — with well-behaved callers that
    /// retire rows on `last`, it only happens for free slots.
    fn decode_step(&mut self, session: &mut DecodeSession) -> Result<StepRows> {
        let bucket = session.bucket;
        let buf = session.state_mut::<BufferedSession>()?;
        let mut out: StepRows = (0..bucket).map(|_| None).collect();
        for (slot, row) in buf.rows.iter_mut().enumerate() {
            if let Some(row) = row {
                out[slot] = row.step();
            }
        }
        Ok(out)
    }

    /// Admit one newly-arrived row into a free slot mid-decode. Returns
    /// whether the row has any natural output (`false` = the engine
    /// should retire it immediately, before the next charged step). The
    /// adapter runs a single-row `generate` and buffers it.
    fn admit_row(&mut self, session: &mut DecodeSession, slot: usize, prompt: &[u32]) -> Result<bool> {
        let plan = BatchPlan {
            job_indices: vec![0],
            bucket: 1,
            len_bucket: session.len_bucket,
            kind: session.kind,
            temperature: session.temperature,
            max_steps: None,
        };
        let natural = self
            .generate(&plan, &[prompt])?
            .pop()
            .ok_or_else(|| Error::Engine("backend returned no rows for admitted job".into()))?;
        let has_work = !natural.is_empty();
        let buf = session.state_mut::<BufferedSession>()?;
        match buf.rows.get_mut(slot) {
            Some(free @ None) => *free = Some(BufferedRow { natural, cursor: 0 }),
            Some(Some(_)) => {
                return Err(Error::Engine(format!("slot {slot} is already occupied")))
            }
            None => {
                return Err(Error::Engine(format!(
                    "slot {slot} out of range for bucket {}",
                    session.bucket
                )))
            }
        }
        Ok(has_work)
    }

    /// Free one slot, abandoning whatever decode work the row had left.
    /// Returns a lower bound on the decode steps genuinely *not*
    /// executed thanks to the retirement — the adapter already ran
    /// `generate` to completion, so it reports 0.
    fn retire_row(&mut self, session: &mut DecodeSession, slot: usize) -> usize {
        if let Ok(buf) = session.state_mut::<BufferedSession>() {
            if let Some(row) = buf.rows.get_mut(slot) {
                *row = None;
            }
        }
        0
    }
}

/// Builds a [`Backend`] *on* the engine thread. The closure is `Send`
/// (it carries paths/configs/seeds), the built backend need not be.
pub type BackendFactory = Box<dyn FnOnce() -> Result<Box<dyn Backend>> + Send>;

// ---------------------------------------------------------------------
// deterministic hashing helpers (shared by the sim emulation)
// ---------------------------------------------------------------------

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn mix(key: u64, salt: u64) -> u64 {
    splitmix64(key ^ salt.wrapping_mul(0xA24B_AED4_963E_E407))
}

fn fnv_tokens(tag: u64, tokens: &[u32]) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64 ^ tag;
    for &t in tokens {
        h ^= t as u64;
        h = h.wrapping_mul(0x100_0000_01B3);
    }
    h
}

/// Map a hash to a unit-interval f64.
fn unit(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

// ---------------------------------------------------------------------
// SimBackend
// ---------------------------------------------------------------------

/// The parsed state of a generation prompt over the task domains: the
/// query's step chain plus how far the written CoT has progressed.
struct ChainState {
    problem: ChainProblem,
    /// Steps already written in the prompt's `S:` section.
    steps_done: usize,
    /// Accumulator after the written steps (the last *written* result —
    /// a slipped step is continued from, like a real LM would).
    acc: i64,
}

/// Parse `Q:<expr>=?\nS:<step;>*` into a [`ChainState`]. The expression
/// grammar (both domains) lives in [`ChainProblem::parse_expr`]. Returns
/// `None` for anything outside the domains (the caller falls back to a
/// deterministic degenerate completion, the way a real LM emits
/// something for any prompt).
fn parse_prompt(text: &str) -> Option<ChainState> {
    let rest = text.strip_prefix("Q:")?;
    let (expr, rest) = rest.split_once("=?")?;
    let rest = rest.strip_prefix('\n')?;
    let problem = ChainProblem::parse_expr(expr)?;
    let body = rest.strip_prefix("S:")?;
    let mut steps_done = 0usize;
    let mut acc = problem.start();
    if !body.is_empty() {
        // chunk prompts always end at a `;` step boundary
        let body = body.strip_suffix(';')?;
        for seg in body.split(';') {
            let (_, written) = seg.rsplit_once('=')?;
            acc = written.parse().ok()?;
            steps_done += 1;
        }
    }
    if steps_done > problem.k() {
        return None;
    }
    Some(ChainState {
        problem,
        steps_done,
        acc,
    })
}

/// A deterministic, artifact-free emulation of the trained generator +
/// PRM + embedders over the synthetic task domains (modular arithmetic
/// and max-value chains — see [`ChainProblem`]).
///
/// Determinism guarantees (relied on by the pool equivalence tests, see
/// `docs/backends.md`):
///
/// * **temperature 0**: generation is a pure function of the prompt
///   tokens — independent of batch shape, call order, engine identity
///   and seed. Serial == coalesced == pool-of-N, bit for bit.
/// * **temperature > 0**: each call draws one key from the backend's
///   seeded RNG (exactly like the device backend's per-call RNG key),
///   and per-step "slips" are derived from (key, row, step). Runs are
///   reproducible given the seed and call sequence, and vary with batch
///   composition just as two serial sampled calls would.
/// * `prm_score` and `embed` are pure functions of their inputs at any
///   temperature.
pub struct SimBackend {
    shapes: EngineShapes,
    clock: SharedClock,
    tokenizer: Tokenizer,
    rng: Rng,
    seed: u64,
    probe_params: Option<Vec<f32>>,
}

/// Per-step slip probability per unit temperature: at the default
/// serving temperature 0.8 each CoT step slips with p ≈ 0.10, so
/// accuracy decays with chain length k — the difficulty gradient the
/// router exploits, reproduced without weights.
const SLIP_PER_TEMPERATURE: f64 = 0.12;

impl SimBackend {
    pub fn new(shapes: EngineShapes, clock: SharedClock, seed: u64, stream: u64) -> SimBackend {
        SimBackend {
            shapes,
            clock,
            tokenizer: Tokenizer::new(),
            rng: Rng::new(seed, 0x51A ^ stream),
            seed,
            probe_params: None,
        }
    }

    /// One row's natural continuation for the given prompt.
    fn continue_row(&self, prompt: &[u32], kind: GenKind, temperature: f32, row_key: u64) -> Result<Vec<u32>> {
        let text = self.tokenizer.decode(prompt)?;
        let out = match parse_prompt(&text) {
            None => {
                // out-of-domain prompt: a deterministic degenerate answer
                format!("A:{}\n", fnv_tokens(7, prompt) % 10)
            }
            Some(state) => {
                let k = state.problem.k();
                let mut acc = state.acc;
                let mut out = String::new();
                let until = match kind {
                    GenKind::Full => k,
                    GenKind::Chunk => (state.steps_done + 1).min(k),
                };
                // per-domain slip difficulty: comparison steps (max
                // domain) slip half as often as arithmetic steps
                let slip_p = (SLIP_PER_TEMPERATURE
                    * temperature as f64
                    * state.problem.slip_factor())
                .min(0.9);
                for i in state.steps_done..until {
                    let (stem, correct) =
                        state.problem.step_stem(i, acc).expect("step in range");
                    let slips = temperature > 0.0 && unit(mix(row_key, i as u64)) < slip_p;
                    let result = if slips {
                        // deterministic wrong digit, never the correct one
                        (correct + 1 + (mix(row_key, i as u64 * 2 + 1) % 8) as i64) % 10
                    } else {
                        correct
                    };
                    out.push_str(&format!("{stem}{result};"));
                    acc = result;
                }
                // Full runs finish with the answer; a chunk only does
                // once every step is already written (the chunk
                // executable stops at `;` otherwise).
                if until == k && (kind == GenKind::Full || state.steps_done == k) {
                    out.push_str(&format!("A:{acc}\n"));
                }
                out
            }
        };
        let mut ids = self.tokenizer.encode(&out)?;
        let cap = match kind {
            GenKind::Full => self.shapes.gen_max_new,
            GenKind::Chunk => self.shapes.chunk_max_new,
        };
        ids.truncate(cap);
        Ok(ids)
    }

    /// Pure scoring of one CoT prefix: recompute the true chain and
    /// count written steps (and the final answer, if present) that
    /// diverge from it. Deterministic jitter breaks ties without
    /// breaking purity.
    fn score_prefix(&self, prefix: &[u32]) -> f32 {
        let jitter = |tag: u64| (unit(fnv_tokens(tag, prefix)) - 0.5) as f32 * 0.06;
        let Ok(text) = self.tokenizer.decode(prefix) else {
            return 0.05;
        };
        let Some((query, body)) = text.split_once("\nS:") else {
            return (0.08 + jitter(11)).clamp(0.01, 0.99);
        };
        let Some(state) = parse_prompt(&format!("{query}\nS:")) else {
            return (0.08 + jitter(11)).clamp(0.01, 0.99);
        };
        let truth = state.problem.step_texts();
        let answer = state.problem.answer().to_string();
        let mut wrongs = 0usize;
        let mut idx = 0usize;
        for seg in body.split(';') {
            let seg = seg.trim_end_matches('\n');
            if seg.is_empty() {
                continue;
            }
            if let Some(ans) = seg.strip_prefix("A:") {
                if ans != answer || idx != truth.len() {
                    wrongs += 1;
                }
            } else if idx >= truth.len() || seg != truth[idx] {
                wrongs += 1;
                idx += 1;
            } else {
                idx += 1;
            }
        }
        let base = 0.92f32 * 0.25f32.powi(wrongs as i32);
        (base + jitter(13)).clamp(0.01, 0.99)
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn shapes(&self) -> &EngineShapes {
        &self.shapes
    }

    fn describe(&self) -> Value {
        Value::obj()
            .with("backend", "sim")
            .with("platform", "sim")
            .with("compile_ms_total", 0.0)
            .with("seed", self.seed)
    }

    fn generate(&mut self, plan: &BatchPlan, prompts: &[&[u32]]) -> Result<Vec<Vec<u32>>> {
        // one key per call, like the device backend's RNG key: sampled
        // rows vary with batch composition, temp-0 rows ignore it
        let call_key = self.rng.next_u64();
        prompts
            .iter()
            .enumerate()
            .map(|(row, p)| {
                let row_key = mix(call_key, row as u64);
                self.continue_row(p, plan.kind, plan.temperature, row_key)
            })
            .collect()
    }

    fn prm_score(&mut self, _bucket: usize, prefixes: &[Vec<u32>]) -> Result<Vec<f32>> {
        // like the device path, an over-long prefix is scored on its
        // first prm_len tokens
        let l = self.shapes.prm_len;
        Ok(prefixes
            .iter()
            .map(|p| self.score_prefix(&p[..p.len().min(l)]))
            .collect())
    }

    fn embed(&mut self, kind: EmbedKind, _bucket: usize, queries: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        let tag = match kind {
            EmbedKind::Pool => 0x90,
            EmbedKind::Small => 0x91,
        };
        let d = self.shapes.d_model;
        Ok(queries
            .iter()
            .map(|q| {
                let mut h = fnv_tokens(tag, q);
                (0..d)
                    .map(|_| {
                        h = splitmix64(h);
                        (unit(h) * 2.0 - 1.0) as f32
                    })
                    .collect()
            })
            .collect())
    }

    fn probe_fwd(&mut self, feats: &[Vec<f32>]) -> Result<Vec<f32>> {
        let f = self.shapes.probe_features;
        let b = self.shapes.probe_fwd_batch;
        let mut out = Vec::with_capacity(feats.len());
        for chunk in feats.chunks(b) {
            for feat in chunk {
                if feat.len() != f {
                    return Err(Error::Engine(format!(
                        "feature row has {} dims, probe expects {f}",
                        feat.len()
                    )));
                }
                // deterministic pseudo-readout: a fixed hash of the
                // feature bits (loaded checkpoint params shift it so
                // installs are observable)
                let mut h = 0x6A09_E667_F3BC_C908u64;
                for v in feat {
                    h ^= v.to_bits() as u64;
                    h = splitmix64(h);
                }
                let shift = self
                    .probe_params
                    .as_ref()
                    .and_then(|p| p.first())
                    .copied()
                    .unwrap_or(0.0);
                out.push((unit(h) * 4.0 - 2.0) as f32 + shift);
            }
            self.clock.charge(CostEvent::Probe { batch: b });
        }
        Ok(out)
    }

    fn probe_train(
        &mut self,
        _train_feats: &[Vec<f32>],
        _train_labels: &[f32],
        _val_feats: &[Vec<f32>],
        _val_labels: &[f32],
        _epochs: usize,
        _patience: usize,
    ) -> Result<ProbeTrainReport> {
        Err(Error::Engine(
            "sim backend does not train the probe — probe training needs the \
             device backend and AOT artifacts (`make artifacts`)"
                .into(),
        ))
    }

    fn probe_load(&mut self, params: Vec<f32>) -> Result<()> {
        if params.is_empty() {
            return Err(Error::Engine("probe blob is empty".into()));
        }
        self.probe_params = Some(params);
        Ok(())
    }

    // -- native stepping ----------------------------------------------
    //
    // The emulator has no real decoder, so "stepping" precomputes each
    // row's natural continuation at admission and replays it one token
    // per step — but unlike the buffered adapter it *reports* the
    // unemitted tail on retirement: exactly the steps a real
    // iteration-level decoder would have skipped, which is what the sim
    // clock's cost model is standing in for.

    fn stepping(&self) -> bool {
        true
    }

    fn prefill(&mut self, plan: &BatchPlan, prompts: &[&[u32]]) -> Result<DecodeSession> {
        // same one-key-per-call draw as `generate`, so the RNG stream
        // (and with it any later sampled call) does not depend on which
        // path the engine routed this plan through
        let call_key = self.rng.next_u64();
        let mut rows: Vec<Option<BufferedRow>> = (0..plan.bucket).map(|_| None).collect();
        let mut empty = Vec::new();
        for (slot, p) in prompts.iter().enumerate() {
            let row_key = mix(call_key, slot as u64);
            let natural = self.continue_row(p, plan.kind, plan.temperature, row_key)?;
            if natural.is_empty() {
                empty.push(slot);
            }
            rows[slot] = Some(BufferedRow { natural, cursor: 0 });
        }
        let mut session = DecodeSession::new(
            plan,
            Box::new(SimSession {
                call_key,
                admits: 0,
                rows,
            }),
        );
        session.empty_rows = empty;
        Ok(session)
    }

    fn decode_step(&mut self, session: &mut DecodeSession) -> Result<StepRows> {
        let bucket = session.bucket;
        let s = session.state_mut::<SimSession>()?;
        let mut out: StepRows = (0..bucket).map(|_| None).collect();
        for (slot, row) in s.rows.iter_mut().enumerate() {
            if let Some(row) = row {
                out[slot] = row.step();
            }
        }
        Ok(out)
    }

    fn admit_row(&mut self, session: &mut DecodeSession, slot: usize, prompt: &[u32]) -> Result<bool> {
        let kind = session.kind;
        let temperature = session.temperature;
        // the admitted row's key derives from the session key without
        // touching the RNG stream: temp-0 byte equivalence with the
        // round path survives mid-decode admission, and sampled rows
        // stay reproducible (the salt is disjoint from initial slots)
        let row_key = {
            let s = session.state_mut::<SimSession>()?;
            match s.rows.get(slot) {
                Some(None) => {}
                Some(Some(_)) => {
                    return Err(Error::Engine(format!("slot {slot} is already occupied")))
                }
                None => {
                    return Err(Error::Engine(format!(
                        "slot {slot} out of range for bucket {}",
                        session.bucket
                    )))
                }
            }
            s.admits += 1;
            mix(s.call_key, (1u64 << 32) + (s.admits << 8) + slot as u64)
        };
        let natural = self.continue_row(prompt, kind, temperature, row_key)?;
        let has_work = !natural.is_empty();
        let s = session.state_mut::<SimSession>()?;
        s.rows[slot] = Some(BufferedRow { natural, cursor: 0 });
        Ok(has_work)
    }

    fn retire_row(&mut self, session: &mut DecodeSession, slot: usize) -> usize {
        let Ok(s) = session.state_mut::<SimSession>() else {
            return 0;
        };
        match s.rows.get_mut(slot).and_then(|r| r.take()) {
            Some(row) => row.natural.len().saturating_sub(row.cursor),
            None => 0,
        }
    }
}

/// Native stepping state of [`SimBackend`] — see the `impl` comment.
struct SimSession {
    /// The per-call RNG key drawn at prefill (mirrors `generate`).
    call_key: u64,
    /// Mid-decode admissions so far (salts admitted rows' keys).
    admits: u64,
    rows: Vec<Option<BufferedRow>>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskgen::{MaxProblem, Problem};
    use crate::util::clock;

    fn sim() -> SimBackend {
        SimBackend::new(
            EngineShapes::sim_default(&EngineConfig::default()),
            clock::sim_clock(),
            7,
            0,
        )
    }

    fn plan(kind: GenKind, temperature: f32, rows: usize) -> BatchPlan {
        BatchPlan {
            job_indices: (0..rows).collect(),
            bucket: rows.next_power_of_two().max(1),
            len_bucket: 32,
            kind,
            temperature,
            max_steps: None,
        }
    }

    #[test]
    fn temp0_full_generation_solves_the_chain() {
        let mut b = sim();
        let tok = Tokenizer::new();
        let mut rng = Rng::new(99, 0);
        for k in 2..=8 {
            let p = Problem::sample(&mut rng, k);
            let prompt = tok.encode(&format!("{}S:", p.query_text())).unwrap();
            let rows = b.generate(&plan(GenKind::Full, 0.0, 1), &[&prompt]).unwrap();
            let text = tok.decode(&rows[0]).unwrap();
            // the continuation is exactly the ground-truth CoT + answer
            assert_eq!(format!("S:{text}"), p.solution_text(), "k={k}");
        }
    }

    #[test]
    fn temp0_is_a_pure_function_of_the_prompt() {
        let tok = Tokenizer::new();
        let prompt = tok.encode("Q:7+8-5=?\nS:").unwrap();
        let mut a = sim();
        // different seed, different batch shape, different call order
        let mut b = SimBackend::new(
            EngineShapes::sim_default(&EngineConfig::default()),
            clock::sim_clock(),
            1234,
            3,
        );
        let _ = b.generate(&plan(GenKind::Full, 0.0, 1), &[&prompt]).unwrap();
        let ra = a.generate(&plan(GenKind::Full, 0.0, 1), &[&prompt]).unwrap();
        let other = tok.encode("Q:2*3+4=?\nS:").unwrap();
        let rb = b
            .generate(&plan(GenKind::Full, 0.0, 2), &[&other, &prompt])
            .unwrap();
        assert_eq!(ra[0], rb[1], "temp-0 rows must not depend on batch/seed/order");
    }

    #[test]
    fn chunk_emits_one_step_then_the_answer() {
        let mut b = sim();
        let tok = Tokenizer::new();
        let prompt = tok.encode("Q:7+8-5=?\nS:").unwrap();
        let step1 = b.generate(&plan(GenKind::Chunk, 0.0, 1), &[&prompt]).unwrap();
        assert_eq!(tok.decode(&step1[0]).unwrap(), "7+8=5;");
        let prompt2 = tok.encode("Q:7+8-5=?\nS:7+8=5;").unwrap();
        let step2 = b.generate(&plan(GenKind::Chunk, 0.0, 1), &[&prompt2]).unwrap();
        assert_eq!(tok.decode(&step2[0]).unwrap(), "5-5=0;");
        let prompt3 = tok.encode("Q:7+8-5=?\nS:7+8=5;5-5=0;").unwrap();
        let fin = b.generate(&plan(GenKind::Chunk, 0.0, 1), &[&prompt3]).unwrap();
        assert_eq!(tok.decode(&fin[0]).unwrap(), "A:0\n");
    }

    #[test]
    fn sampled_generation_slips_reproducibly() {
        let tok = Tokenizer::new();
        let prompt = tok.encode("Q:7+8-5+2*6-3+4+8=?\nS:").unwrap();
        let run = |seed| {
            let mut b = SimBackend::new(
                EngineShapes::sim_default(&EngineConfig::default()),
                clock::sim_clock(),
                seed,
                0,
            );
            let prompts: Vec<&[u32]> = (0..16).map(|_| prompt.as_slice()).collect();
            b.generate(&plan(GenKind::Full, 0.9, 16), &prompts).unwrap()
        };
        assert_eq!(run(5), run(5), "same seed + call sequence reproduces");
        // across 16 hot-temperature rows of a 7-step chain, at least one
        // row should slip somewhere (p ≈ 1 - (1-.108)^(7·16) ≈ 1)
        let rows = run(5);
        let truth = run_temp0(&prompt);
        assert!(
            rows.iter().any(|r| r != &truth),
            "no slip across 16 sampled rows"
        );
    }

    fn run_temp0(prompt: &[u32]) -> Vec<u32> {
        let mut b = sim();
        b.generate(&plan(GenKind::Full, 0.0, 1), &[prompt]).unwrap().remove(0)
    }

    #[test]
    fn prm_separates_correct_from_corrupted_prefixes() {
        let mut b = sim();
        let tok = Tokenizer::new();
        let good = tok.encode("Q:7+8-5=?\nS:7+8=5;5-5=0;A:0\n").unwrap();
        let bad = tok.encode("Q:7+8-5=?\nS:7+8=6;6-5=1;A:1\n").unwrap();
        let partial_good = tok.encode("Q:7+8-5=?\nS:7+8=5;").unwrap();
        let scores = b.prm_score(4, &[good, bad, partial_good]).unwrap();
        assert!(scores[0] > 0.8, "correct full solution: {}", scores[0]);
        assert!(scores[1] < 0.3, "corrupted solution: {}", scores[1]);
        assert!(scores[2] > 0.8, "correct partial prefix: {}", scores[2]);
    }

    #[test]
    fn embeddings_are_pure_and_kind_distinct() {
        let mut b = sim();
        let tok = Tokenizer::new();
        let q = tok.encode("Q:7+8-5=?\n").unwrap();
        let a = b.embed(EmbedKind::Pool, 1, &[q.clone()]).unwrap();
        let c = b.embed(EmbedKind::Pool, 1, &[q.clone()]).unwrap();
        let d = b.embed(EmbedKind::Small, 1, &[q]).unwrap();
        assert_eq!(a, c);
        assert_ne!(a, d);
        assert_eq!(a[0].len(), b.shapes().d_model);
    }

    #[test]
    fn probe_fwd_validates_width_and_observes_installs() {
        let mut b = sim();
        let f = b.shapes().probe_features;
        assert!(b.probe_fwd(&[vec![0.0; f - 1]]).is_err());
        let before = b.probe_fwd(&[vec![0.5; f]]).unwrap()[0];
        b.probe_load(vec![1.5, 0.0]).unwrap();
        let after = b.probe_fwd(&[vec![0.5; f]]).unwrap()[0];
        assert!((after - before - 1.5).abs() < 1e-6);
        assert!(b.probe_load(vec![]).is_err());
    }

    #[test]
    fn out_of_domain_prompt_degenerates_deterministically() {
        let mut b = sim();
        let tok = Tokenizer::new();
        let junk = tok.encode("S:;;==").unwrap();
        let r1 = b.generate(&plan(GenKind::Full, 0.0, 1), &[&junk]).unwrap();
        let r2 = b.generate(&plan(GenKind::Full, 0.0, 1), &[&junk]).unwrap();
        assert_eq!(r1, r2);
        let text = tok.decode(&r1[0]).unwrap();
        assert!(text.starts_with("A:") && text.ends_with('\n'), "{text:?}");
    }

    // -- max-value domain ---------------------------------------------

    #[test]
    fn temp0_solves_max_chains_too() {
        let mut b = sim();
        let tok = Tokenizer::new();
        let mut rng = Rng::new(41, 0);
        for k in 2..=8 {
            let p = MaxProblem::sample(&mut rng, k);
            let prompt = tok.encode(&format!("{}S:", p.query_text())).unwrap();
            let rows = b.generate(&plan(GenKind::Full, 0.0, 1), &[&prompt]).unwrap();
            let text = tok.decode(&rows[0]).unwrap();
            assert_eq!(format!("S:{text}"), p.solution_text(), "k={k}");
        }
    }

    #[test]
    fn chunk_steps_the_max_domain() {
        let mut b = sim();
        let tok = Tokenizer::new();
        let prompt = tok.encode("Q:max(3,8,5)=?\nS:").unwrap();
        let step1 = b.generate(&plan(GenKind::Chunk, 0.0, 1), &[&prompt]).unwrap();
        assert_eq!(tok.decode(&step1[0]).unwrap(), "max(3,8)=8;");
        let prompt2 = tok.encode("Q:max(3,8,5)=?\nS:max(3,8)=8;").unwrap();
        let step2 = b.generate(&plan(GenKind::Chunk, 0.0, 1), &[&prompt2]).unwrap();
        assert_eq!(tok.decode(&step2[0]).unwrap(), "max(8,5)=8;");
        let prompt3 = tok
            .encode("Q:max(3,8,5)=?\nS:max(3,8)=8;max(8,5)=8;")
            .unwrap();
        let fin = b.generate(&plan(GenKind::Chunk, 0.0, 1), &[&prompt3]).unwrap();
        assert_eq!(tok.decode(&fin[0]).unwrap(), "A:8\n");
    }

    #[test]
    fn prm_separates_max_domain_prefixes() {
        let mut b = sim();
        let tok = Tokenizer::new();
        let good = tok
            .encode("Q:max(3,8,5)=?\nS:max(3,8)=8;max(8,5)=8;A:8\n")
            .unwrap();
        let bad = tok
            .encode("Q:max(3,8,5)=?\nS:max(3,8)=3;max(3,5)=5;A:5\n")
            .unwrap();
        let scores = b.prm_score(4, &[good, bad]).unwrap();
        assert!(scores[0] > 0.8, "correct max solution: {}", scores[0]);
        assert!(scores[1] < 0.3, "corrupted max solution: {}", scores[1]);
    }

    #[test]
    fn max_steps_slip_less_than_arith_at_equal_keys() {
        // Same seed + call sequence ⇒ identical row keys, so each
        // row/step draws the same uniform on both backends. The max
        // domain's slip threshold is half the arith one
        // (slip_factor 0.5), so its slip set is a strict subset across
        // 16 rows × 8 steps — the heterogeneous difficulty gradient
        // agentic chains mix.
        let tok = Tokenizer::new();
        let arith = tok.encode("Q:7+8-5+2*6-3+4+8=?\nS:").unwrap();
        let maxq = tok.encode("Q:max(1,2,3,4,5,6,7,8,9)=?\nS:").unwrap();
        let count_slipped = |prompt: &[u32]| {
            let mut b = sim();
            let truth = run_temp0(prompt);
            let prompts: Vec<&[u32]> = (0..16).map(|_| prompt).collect();
            let rows = b.generate(&plan(GenKind::Full, 0.9, 16), &prompts).unwrap();
            rows.iter().filter(|r| *r != &truth).count()
        };
        let arith_slipped = count_slipped(&arith);
        let max_slipped = count_slipped(&maxq);
        assert!(
            arith_slipped > max_slipped,
            "arith rows slipped {arith_slipped}, max rows slipped {max_slipped}"
        );
    }

    // -- steppable session API ----------------------------------------

    /// A backend that does NOT override the steppable methods, to
    /// exercise the provided run-to-completion adapter.
    struct Legacy(SimBackend);

    impl Backend for Legacy {
        fn name(&self) -> &'static str {
            "legacy"
        }
        fn shapes(&self) -> &EngineShapes {
            self.0.shapes()
        }
        fn describe(&self) -> Value {
            self.0.describe()
        }
        fn generate(&mut self, plan: &BatchPlan, prompts: &[&[u32]]) -> Result<Vec<Vec<u32>>> {
            self.0.generate(plan, prompts)
        }
        fn prm_score(&mut self, bucket: usize, prefixes: &[Vec<u32>]) -> Result<Vec<f32>> {
            self.0.prm_score(bucket, prefixes)
        }
        fn embed(&mut self, kind: EmbedKind, bucket: usize, queries: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
            self.0.embed(kind, bucket, queries)
        }
        fn probe_fwd(&mut self, feats: &[Vec<f32>]) -> Result<Vec<f32>> {
            self.0.probe_fwd(feats)
        }
        fn probe_train(
            &mut self,
            a: &[Vec<f32>],
            b: &[f32],
            c: &[Vec<f32>],
            d: &[f32],
            e: usize,
            f: usize,
        ) -> Result<ProbeTrainReport> {
            self.0.probe_train(a, b, c, d, e, f)
        }
        fn probe_load(&mut self, params: Vec<f32>) -> Result<()> {
            self.0.probe_load(params)
        }
    }

    /// Drive a session to completion, returning per-slot token vectors.
    fn step_to_end(b: &mut dyn Backend, session: &mut DecodeSession) -> Vec<Vec<u32>> {
        let mut out = vec![Vec::new(); session.bucket];
        let mut live: Vec<bool> = (0..session.bucket).map(|_| true).collect();
        for e in &session.empty_rows {
            live[*e] = false;
        }
        loop {
            let rows = b.decode_step(session).unwrap();
            let mut any = false;
            for (slot, tok) in rows.into_iter().enumerate() {
                let Some(tok) = tok else { continue };
                any = true;
                out[slot].push(tok.token);
                if tok.last {
                    live[slot] = false;
                    b.retire_row(session, slot);
                }
            }
            if !any {
                break;
            }
        }
        out
    }

    #[test]
    fn stepping_flags_native_vs_adapter() {
        assert!(sim().stepping());
        assert!(!Legacy(sim()).stepping());
    }

    #[test]
    fn stepped_session_matches_generate_at_temp0() {
        let tok = Tokenizer::new();
        let p1 = tok.encode("Q:7+8-5=?\nS:").unwrap();
        let p2 = tok.encode("Q:2*3+4=?\nS:").unwrap();
        let pl = plan(GenKind::Full, 0.0, 2);
        let expect = sim().generate(&pl, &[&p1, &p2]).unwrap();
        // native sim stepping
        let mut nat = sim();
        let mut session = nat.prefill(&pl, &[&p1, &p2]).unwrap();
        assert_eq!(step_to_end(&mut nat, &mut session), expect);
        // buffered adapter over a legacy backend
        let mut leg = Legacy(sim());
        let mut session = leg.prefill(&pl, &[&p1, &p2]).unwrap();
        assert_eq!(step_to_end(&mut leg, &mut session), expect);
    }

    #[test]
    fn native_retire_reports_unspent_tail_adapter_reports_zero() {
        let tok = Tokenizer::new();
        let prompt = tok.encode("Q:7+8-5+2*6=?\nS:").unwrap();
        let pl = plan(GenKind::Full, 0.0, 1);
        let natural_len = sim().generate(&pl, &[&prompt]).unwrap()[0].len();
        assert!(natural_len > 3, "need a multi-step natural for this test");

        let mut nat = sim();
        let mut session = nat.prefill(&pl, &[&prompt]).unwrap();
        for _ in 0..3 {
            let rows = nat.decode_step(&mut session).unwrap();
            assert!(rows[0].is_some());
        }
        assert_eq!(nat.retire_row(&mut session, 0), natural_len - 3);
        // the slot is free now: nothing further steps
        assert!(nat.decode_step(&mut session).unwrap()[0].is_none());
        // double-retire is a no-op
        assert_eq!(nat.retire_row(&mut session, 0), 0);

        let mut leg = Legacy(sim());
        let mut session = leg.prefill(&pl, &[&prompt]).unwrap();
        leg.decode_step(&mut session).unwrap();
        assert_eq!(leg.retire_row(&mut session, 0), 0, "adapter saves nothing");
    }

    #[test]
    fn admit_row_mid_session_matches_temp0_generate() {
        let tok = Tokenizer::new();
        let p1 = tok.encode("Q:7+8-5=?\nS:").unwrap();
        let p2 = tok.encode("Q:2*3+4=?\nS:").unwrap();
        let expect2 = sim().generate(&plan(GenKind::Full, 0.0, 1), &[&p2]).unwrap();
        let mut b = sim();
        // bucket of 2 with one initial row; the second joins mid-decode
        let mut pl = plan(GenKind::Full, 0.0, 1);
        pl.bucket = 2;
        let mut session = b.prefill(&pl, &[&p1]).unwrap();
        assert_eq!(session.bucket, 2);
        b.decode_step(&mut session).unwrap();
        assert!(b.admit_row(&mut session, 1, &p2).unwrap());
        // occupied / out-of-range slots are rejected
        assert!(b.admit_row(&mut session, 1, &p2).is_err());
        assert!(b.admit_row(&mut session, 9, &p2).is_err());
        let out = step_to_end(&mut b, &mut session);
        assert_eq!(out[1], expect2[0], "admitted temp-0 row matches generate");
    }

    #[test]
    fn prefill_leaves_empty_rows_clear_for_live_prompts() {
        let tok = Tokenizer::new();
        let p1 = tok.encode("Q:7+8-5=?\nS:").unwrap();
        let mut b = sim();
        let session = b.prefill(&plan(GenKind::Full, 0.0, 1), &[&p1]).unwrap();
        assert!(session.empty_rows.is_empty());
    }
}
