//! `EnginePool`: N backend-driven engines behind one [`EngineHandle`].
//!
//! The one axis a deployment actually scales is replicas, so the pool
//! makes engines *plural* without changing the client contract: every
//! submission (`submit_generate` / `submit_prm_score` / blocking
//! `generate` / `prm_score` / `embed` / `probe_fwd`) routes through a
//! placement policy, and each engine keeps its own coalescing scheduler,
//! budget preemption and metrics exactly as in the single-engine case.
//!
//! ## Placement policy
//!
//! [`place`] is a pure function over per-engine load snapshots:
//!
//! 1. **least outstanding rows** — rows (generate jobs, PRM prefixes,
//!    embed queries, probe feature rows) submitted and not yet replied;
//! 2. tie → **fewest outstanding calls**;
//! 3. tie → **deadline-aware (EDF) tiebreak**: prefer the engine whose
//!    most-urgent outstanding deadline is *latest* — new work (urgent or
//!    not) avoids stacking behind an engine already racing a tight
//!    deadline, which is what lets tight-deadline traffic meet its
//!    budget while unlimited traffic fills the remaining capacity;
//! 4. tie → lowest engine index (deterministic).
//!
//! Accounting is released when the requester *receives* the reply (or
//! drops it) — see [`PoolGuard`] — so "outstanding" means submitted and
//! not yet harvested, the quantity a scheduler can actually observe.
//!
//! ## Error semantics
//!
//! Within one engine, a failed coalesced call still broadcasts the error
//! to every coalesced requester (single-engine contract, unchanged).
//! Submitting to an engine whose thread is gone returns a deterministic,
//! descriptive [`Error::Engine`] naming the engine and the operation —
//! not a bare channel-closed unwrap — and rolls the placement
//! reservation back.
//!
//! ## Determinism
//!
//! Temperature-0 generation, PRM scoring and embedding are pure
//! functions of their inputs on every backend, so results are identical
//! for pool sizes 1, 2, 4, … — property- and integration-tested in
//! `tests/integration_pool.rs`. Under the *sim clock*, pool engines
//! share one virtual timeline (charges add), so sim time measures total
//! compute rather than wall parallelism; real-clock runs overlap for
//! real.

use crate::config::Config;
use crate::engine::handle::{Engine, EngineHandle};
use crate::engine::protocol::EngineMsg;
use crate::error::{Error, Result};
use crate::metrics::{EngineMetrics, PoolMetrics};
use crate::util::clock::{self, SharedClock};
use crate::util::json::Value;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

/// One engine's load snapshot, as the placement policy sees it.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EngineLoad {
    /// Rows submitted and not yet harvested.
    pub rows: usize,
    /// Calls submitted and not yet harvested.
    pub calls: usize,
    /// Absolute deadlines of the outstanding calls
    /// (`f64::INFINITY` for calls without one).
    pub deadlines: Vec<f64>,
}

impl EngineLoad {
    /// The most urgent outstanding deadline (`INFINITY` when none).
    pub fn min_deadline(&self) -> f64 {
        self.deadlines.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

/// Pure placement: pick the engine for the next submission. See the
/// module docs for the full policy; `loads` must be non-empty.
pub fn place(loads: &[EngineLoad]) -> usize {
    let mut best = 0usize;
    for i in 1..loads.len() {
        let (a, b) = (&loads[i], &loads[best]);
        let better = match a.rows.cmp(&b.rows) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => match a.calls.cmp(&b.calls) {
                std::cmp::Ordering::Less => true,
                std::cmp::Ordering::Greater => false,
                // EDF-aware: latest most-urgent deadline wins the tie
                // (strict >, so a full tie keeps the lowest index)
                std::cmp::Ordering::Equal => a.min_deadline() > b.min_deadline(),
            },
        };
        if better {
            best = i;
        }
    }
    best
}

/// Whether [`place`] chose differently than plain least-rows/calls
/// argmin would — i.e. the deadline tiebreak decided (metric feed).
fn deadline_tiebreak_decided(loads: &[EngineLoad], chosen: usize) -> bool {
    let plain = loads
        .iter()
        .enumerate()
        .min_by_key(|(_, l)| (l.rows, l.calls))
        .map(|(i, _)| i)
        .unwrap_or(0);
    chosen != plain
}

/// One engine's routing endpoint inside the router.
struct Slot {
    /// Mutex so the shared router stays `Sync` regardless of the
    /// `Sender` `Sync`-ness of the toolchain; submissions are rare
    /// relative to device work, so contention is irrelevant.
    tx: Mutex<Sender<EngineMsg>>,
    metrics: Arc<EngineMetrics>,
}

/// Shared routing state behind pool-backed [`EngineHandle`]s.
pub struct PoolRouter {
    slots: Vec<Slot>,
    loads: Mutex<Vec<EngineLoad>>,
    pub metrics: PoolMetrics,
}

impl PoolRouter {
    pub fn engines(&self) -> usize {
        self.slots.len()
    }

    /// Place and send one accounted submission. Returns the guard that
    /// releases the reservation when the reply is harvested/dropped.
    pub(crate) fn submit(
        self: &Arc<Self>,
        msg: EngineMsg,
        rows: usize,
        deadline_ms: f64,
        op: &'static str,
    ) -> Result<PoolGuard> {
        let idx = {
            let mut loads = self.loads.lock().unwrap();
            let idx = place(&loads);
            if deadline_tiebreak_decided(&loads, idx) {
                self.metrics.deadline_tiebreaks.inc();
            }
            loads[idx].rows += rows;
            loads[idx].calls += 1;
            loads[idx].deadlines.push(deadline_ms);
            idx
        };
        self.metrics.placements.inc();
        self.metrics.engine(idx).submits.inc();
        self.metrics.engine(idx).rows_submitted.add(rows as u64);
        let sent = { self.slots[idx].tx.lock().unwrap().send(msg) };
        if sent.is_err() {
            self.release(idx, rows, deadline_ms);
            return Err(Self::engine_down(idx, self.slots.len(), op));
        }
        Ok(PoolGuard {
            router: self.clone(),
            engine: idx,
            rows,
            deadline_ms,
        })
    }

    /// Send a control-plane message to a specific engine (no load
    /// accounting — probe train/load, info).
    pub(crate) fn send_to(&self, idx: usize, msg: EngineMsg, op: &'static str) -> Result<()> {
        self.slots[idx]
            .tx
            .lock()
            .unwrap()
            .send(msg)
            .map_err(|_| Self::engine_down(idx, self.slots.len(), op))
    }

    /// Install probe params on every engine from `from` up — replicas
    /// must answer probe queries identically no matter where a request
    /// lands. The first failure wins (and names its engine).
    pub(crate) fn broadcast_probe_load(&self, params: Vec<f32>, from: usize) -> Result<()> {
        let mut replies = Vec::new();
        for idx in from..self.slots.len() {
            let (reply, rx) = channel();
            self.send_to(
                idx,
                EngineMsg::ProbeLoad {
                    params: params.clone(),
                    reply,
                },
                "probe_load",
            )?;
            replies.push((idx, rx));
        }
        for (idx, rx) in replies {
            rx.recv().map_err(|_| {
                Self::engine_down(idx, self.slots.len(), "probe_load")
            })??;
        }
        Ok(())
    }

    fn engine_down(idx: usize, n: usize, op: &'static str) -> Error {
        Error::Engine(format!(
            "pool engine #{idx} (of {n}) is shut down — {op} submission rejected"
        ))
    }

    /// Release one submission's reservation (reply harvested or
    /// dropped).
    fn release(&self, idx: usize, rows: usize, deadline_ms: f64) {
        let mut loads = self.loads.lock().unwrap();
        let l = &mut loads[idx];
        l.rows = l.rows.saturating_sub(rows);
        l.calls = l.calls.saturating_sub(1);
        if let Some(pos) = l
            .deadlines
            .iter()
            .position(|d| d.to_bits() == deadline_ms.to_bits())
        {
            l.deadlines.swap_remove(pos);
        }
        self.metrics.engine(idx).rows_completed.add(rows as u64);
    }

    /// Placement + per-engine utilization as JSON (embedded in `info()`
    /// and the serve report).
    pub fn report(&self) -> Value {
        let engines: Vec<&Arc<EngineMetrics>> = self.slots.iter().map(|s| &s.metrics).collect();
        build_report(&engines, Some(&self.metrics))
    }
}

/// One report builder for every pool size, so a consumer written
/// against the N-engine shape never sees different keys from a pool
/// that happens to be size 1 (placement counters simply read 0 there).
fn build_report(engines: &[&Arc<EngineMetrics>], pool: Option<&PoolMetrics>) -> Value {
    let mut per_engine = Vec::with_capacity(engines.len());
    let mut served: Vec<u64> = Vec::with_capacity(engines.len());
    for (i, m) in engines.iter().enumerate() {
        served.push(m.rows_served());
        let routing = pool.map(|p| p.engine(i));
        per_engine.push(
            Value::obj()
                .with("engine", i)
                .with("submits", routing.map_or(0, |r| r.submits.get()))
                .with("rows_submitted", routing.map_or(0, |r| r.rows_submitted.get()))
                .with("rows_completed", routing.map_or(0, |r| r.rows_completed.get()))
                .with("rows_served", m.rows_served())
                .with("decode_rows", m.decode_rows.get())
                .with("prm_rows", m.prm_rows.get())
                .with("embed_rows", m.embed_rows.get())
                .with("preempted_rows", m.preempted_rows.get())
                .with("tokens_generated", m.tokens_generated.get()),
        );
    }
    let total: u64 = served.iter().sum();
    Value::obj()
        .with("engines", engines.len())
        .with("placements", pool.map_or(0, |p| p.placements.get()))
        .with(
            "deadline_tiebreaks",
            pool.map_or(0, |p| p.deadline_tiebreaks.get()),
        )
        .with("balance_ratio", balance_ratio(&served))
        .with("rows_served_total", total)
        .with("per_engine", Value::Arr(per_engine))
}

fn balance_ratio(served: &[u64]) -> f64 {
    let max = served.iter().copied().max().unwrap_or(0);
    let min = served.iter().copied().min().unwrap_or(0);
    max.max(1) as f64 / min.max(1) as f64
}

/// Releases one pool submission's placement accounting on drop; the
/// reply plumbing settles it as soon as the result is received.
pub struct PoolGuard {
    router: Arc<PoolRouter>,
    engine: usize,
    rows: usize,
    deadline_ms: f64,
}

impl Drop for PoolGuard {
    fn drop(&mut self) {
        self.router.release(self.engine, self.rows, self.deadline_ms);
    }
}

/// Owns N engines plus the router that places work across them.
pub struct EnginePool {
    engines: Vec<Engine>,
    router: Option<Arc<PoolRouter>>,
    pub clock: SharedClock,
}

impl EnginePool {
    /// Spawn `cfg.engine.engines` engines (min 1) sharing one clock.
    /// With one engine the pool hands out a plain single-engine handle —
    /// the placement layer is bypassed entirely, so the pool-size-1 path
    /// is bit-for-bit the historical single-engine path.
    pub fn start(cfg: &Config) -> Result<EnginePool> {
        let clock: SharedClock = if cfg.engine.sim_clock {
            clock::sim_clock()
        } else {
            clock::real_clock()
        };
        Self::start_with_clock(cfg, clock)
    }

    pub fn start_with_clock(cfg: &Config, clock: SharedClock) -> Result<EnginePool> {
        let n = cfg.engine.engines.max(1);
        let mut engines = Vec::with_capacity(n);
        for i in 0..n {
            engines.push(Engine::start_member(cfg, clock.clone(), i)?);
        }
        let router = if n > 1 {
            Some(Arc::new(PoolRouter {
                slots: engines
                    .iter()
                    .map(|e| Slot {
                        tx: Mutex::new(e.sender()),
                        metrics: e.metrics.clone(),
                    })
                    .collect(),
                loads: Mutex::new(vec![EngineLoad::default(); n]),
                metrics: PoolMetrics::new(n),
            }))
        } else {
            None
        };
        Ok(EnginePool {
            engines,
            router,
            clock,
        })
    }

    pub fn engines(&self) -> usize {
        self.engines.len()
    }

    /// The client handle: single-engine for a pool of 1, placement-
    /// routed otherwise.
    pub fn handle(&self) -> EngineHandle {
        match &self.router {
            None => self.engines[0].handle(),
            Some(router) => EngineHandle::pooled(router.clone()),
        }
    }

    /// Per-engine metrics (engine `i`).
    pub fn engine_metrics(&self, i: usize) -> &Arc<EngineMetrics> {
        &self.engines[i].metrics
    }

    /// max/min rows served across the pool's engines.
    pub fn balance_ratio(&self) -> f64 {
        let served: Vec<u64> = self.engines.iter().map(|e| e.metrics.rows_served()).collect();
        balance_ratio(&served)
    }

    /// The pool report (placement counters + per-engine utilization);
    /// available even for a pool of 1 (same shape, placement counters
    /// read 0 because the single-engine handle bypasses the router).
    pub fn report(&self) -> Value {
        match &self.router {
            Some(router) => router.report(),
            None => {
                let engines: Vec<&Arc<EngineMetrics>> =
                    self.engines.iter().map(|e| &e.metrics).collect();
                build_report(&engines, None)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, gen_vec, prop_assert};

    fn load(rows: usize, calls: usize, deadlines: &[f64]) -> EngineLoad {
        EngineLoad {
            rows,
            calls,
            deadlines: deadlines.to_vec(),
        }
    }

    #[test]
    fn place_prefers_least_rows_then_calls_then_index() {
        let loads = vec![load(4, 1, &[]), load(2, 3, &[]), load(2, 1, &[])];
        assert_eq!(place(&loads), 2);
        let tie = vec![load(2, 1, &[]), load(2, 1, &[])];
        assert_eq!(place(&tie), 0, "full tie keeps the lowest index");
    }

    #[test]
    fn place_edf_tiebreak_avoids_urgent_backlogs() {
        // engines tied on rows/calls; #0 is racing a 100ms deadline,
        // #1's outstanding work is unconstrained → new work goes to #1
        let loads = vec![
            load(4, 1, &[100.0]),
            load(4, 1, &[f64::INFINITY]),
        ];
        assert_eq!(place(&loads), 1);
        // and between two constrained engines, the later deadline wins
        let loads = vec![load(4, 1, &[100.0]), load(4, 1, &[900.0])];
        assert_eq!(place(&loads), 1);
    }

    #[test]
    fn min_deadline_of_empty_is_infinite() {
        assert_eq!(load(0, 0, &[]).min_deadline(), f64::INFINITY);
        assert_eq!(load(0, 0, &[7.0, 3.0]).min_deadline(), 3.0);
    }

    /// Random arrival/completion interleavings against a model: every
    /// job lands on exactly one engine, placement always picks a
    /// least-loaded engine (by rows) at decision time, and the
    /// accounting returns to zero once everything completes.
    #[test]
    fn prop_placement_least_loaded_and_conserving() {
        forall(
            "pool placement invariants",
            150,
            |rng| {
                let engines = rng.range(1, 5) as usize;
                let events = gen_vec(rng, 1..40, |r| {
                    // (arrival? , rows, deadline-bucket)
                    (
                        r.below(3) < 2, // 2/3 arrivals, 1/3 completions
                        r.range(1, 9) as usize,
                        r.below(4),
                    )
                });
                (engines, events)
            },
            |(engines, events)| {
                let mut loads = vec![EngineLoad::default(); *engines];
                // outstanding jobs: (engine, rows, deadline)
                let mut outstanding: Vec<(usize, usize, f64)> = Vec::new();
                let mut placed = 0usize;
                for &(arrive, rows, dbucket) in events {
                    if arrive {
                        let deadline = match dbucket {
                            0 => 100.0,
                            1 => 1000.0,
                            2 => 10_000.0,
                            _ => f64::INFINITY,
                        };
                        let idx = place(&loads);
                        prop_assert(idx < *engines, "placement out of range".to_string())?;
                        let min_rows = loads.iter().map(|l| l.rows).min().unwrap();
                        prop_assert(
                            loads[idx].rows == min_rows,
                            format!(
                                "picked engine {idx} with {} rows, min is {min_rows}",
                                loads[idx].rows
                            ),
                        )?;
                        loads[idx].rows += rows;
                        loads[idx].calls += 1;
                        loads[idx].deadlines.push(deadline);
                        outstanding.push((idx, rows, deadline));
                        placed += 1;
                    } else if !outstanding.is_empty() {
                        // complete the oldest outstanding job
                        let (idx, rows, deadline) = outstanding.remove(0);
                        let l = &mut loads[idx];
                        l.rows -= rows;
                        l.calls -= 1;
                        let pos = l
                            .deadlines
                            .iter()
                            .position(|d| d.to_bits() == deadline.to_bits())
                            .expect("deadline tracked");
                        l.deadlines.swap_remove(pos);
                    }
                }
                // drain the rest; accounting must conserve exactly
                for (idx, rows, deadline) in outstanding.drain(..) {
                    let l = &mut loads[idx];
                    l.rows -= rows;
                    l.calls -= 1;
                    let pos = l
                        .deadlines
                        .iter()
                        .position(|d| d.to_bits() == deadline.to_bits())
                        .expect("deadline tracked");
                    l.deadlines.swap_remove(pos);
                }
                for (i, l) in loads.iter().enumerate() {
                    prop_assert(
                        l.rows == 0 && l.calls == 0 && l.deadlines.is_empty(),
                        format!("engine {i} accounting leaked: {l:?}"),
                    )?;
                }
                prop_assert(placed <= events.len(), "jobs placed once each".to_string())
            },
        );
    }

    #[test]
    fn balance_ratio_clamps_zero_servers() {
        assert_eq!(balance_ratio(&[10, 10]), 1.0);
        assert_eq!(balance_ratio(&[20, 10]), 2.0);
        assert_eq!(balance_ratio(&[10, 0]), 10.0);
        assert_eq!(balance_ratio(&[]), 1.0);
    }
}
