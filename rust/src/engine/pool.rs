//! `EnginePool`: N backend-driven engines behind one [`EngineHandle`].
//!
//! The one axis a deployment actually scales is replicas, so the pool
//! makes engines *plural* without changing the client contract: every
//! submission (`submit_generate` / `submit_prm_score` / blocking
//! `generate` / `prm_score` / `embed` / `probe_fwd`) routes through a
//! placement policy, and each engine keeps its own coalescing scheduler,
//! budget preemption and metrics exactly as in the single-engine case.
//!
//! ## Placement policy
//!
//! [`place_live`] is a pure function over per-engine load snapshots and
//! a liveness mask:
//!
//! 1. **dead engines are excluded** — an engine whose thread is gone,
//!    or whose remote shard stopped answering, takes no new work;
//! 2. **least outstanding rows** — rows (generate jobs, PRM prefixes,
//!    embed queries, probe feature rows) submitted and not yet replied;
//! 3. tie → **fewest outstanding calls**;
//! 4. tie → **deadline-aware (EDF) tiebreak**: prefer the engine whose
//!    most-urgent outstanding deadline is *latest* — new work (urgent or
//!    not) avoids stacking behind an engine already racing a tight
//!    deadline, which is what lets tight-deadline traffic meet its
//!    budget while unlimited traffic fills the remaining capacity;
//! 5. tie → lowest engine index (deterministic).
//!
//! Accounting is released when the requester *receives* the reply (or
//! drops it) — see [`PoolGuard`] — so "outstanding" means submitted and
//! not yet harvested, the quantity a scheduler can actually observe.
//!
//! ## Health, failover and error semantics
//!
//! Within one engine, a failed coalesced call still broadcasts the error
//! to every coalesced requester (single-engine contract, unchanged; the
//! broadcast preserves transience via [`Error::replicate`]).
//!
//! An engine is **marked dead** the first time a submission to it fails
//! (its channel closed) or an in-flight reply comes back as a transient
//! net fault / dropped reply channel. Dead engines are excluded from
//! placement, and the failed submission is *re-placed* on a live engine
//! — counted in `PoolMetrics::rerouted_submits` — rather than failing
//! the request. Only when every engine is down does a submission fail,
//! with a deterministic "all N pool engines are down" [`Error::Engine`].
//! In-flight replies get the same treatment through
//! [`crate::engine::handle::PendingReply`], which holds a resubmittable
//! copy of the request payload for pool-routed submissions.
//!
//! ## Determinism
//!
//! Temperature-0 generation, PRM scoring and embedding are pure
//! functions of their inputs on every backend, so results are identical
//! for pool sizes 1, 2, 4, … — property- and integration-tested in
//! `tests/integration_pool.rs`. Under the *sim clock*, pool engines
//! share one virtual timeline (charges add), so sim time measures total
//! compute rather than wall parallelism; real-clock runs overlap for
//! real.

use crate::config::Config;
use crate::engine::backend::BackendFactory;
use crate::engine::cache::EngineCache;
use crate::engine::handle::{Engine, EngineHandle};
use crate::engine::protocol::EngineMsg;
use crate::error::{Error, Result};
use crate::metrics::{EngineMetrics, PoolMetrics};
use crate::util::clock::{self, SharedClock};
use crate::util::json::Value;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

/// One engine's load snapshot, as the placement policy sees it.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EngineLoad {
    /// Rows submitted and not yet harvested.
    pub rows: usize,
    /// Calls submitted and not yet harvested.
    pub calls: usize,
    /// Absolute deadlines of the outstanding calls
    /// (`f64::INFINITY` for calls without one).
    pub deadlines: Vec<f64>,
}

impl EngineLoad {
    /// The most urgent outstanding deadline (`INFINITY` when none).
    pub fn min_deadline(&self) -> f64 {
        self.deadlines.iter().copied().fold(f64::INFINITY, f64::min)
    }
}

/// Pure placement over all-live engines (compatibility wrapper around
/// [`place_live`]); `loads` must be non-empty.
pub fn place(loads: &[EngineLoad]) -> usize {
    place_live(loads, &[]).expect("place() requires a non-empty load set")
}

/// Pure placement: pick the engine for the next submission among live
/// engines (see the module docs for the full policy). `dead[i]` marks
/// engine `i` excluded; a short (or empty) `dead` slice means the
/// remaining engines are live. `None` = every engine is dead.
pub fn place_live(loads: &[EngineLoad], dead: &[bool]) -> Option<usize> {
    let is_dead = |i: usize| dead.get(i).copied().unwrap_or(false);
    let mut best: Option<usize> = None;
    for i in 0..loads.len() {
        if is_dead(i) {
            continue;
        }
        let Some(b) = best else {
            best = Some(i);
            continue;
        };
        let (a, b_load) = (&loads[i], &loads[b]);
        let better = match a.rows.cmp(&b_load.rows) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => match a.calls.cmp(&b_load.calls) {
                std::cmp::Ordering::Less => true,
                std::cmp::Ordering::Greater => false,
                // EDF-aware: latest most-urgent deadline wins the tie
                // (strict >, so a full tie keeps the lowest index)
                std::cmp::Ordering::Equal => a.min_deadline() > b_load.min_deadline(),
            },
        };
        if better {
            best = Some(i);
        }
    }
    best
}

/// Whether placement chose differently than plain least-rows/calls
/// argmin over *live* engines would — i.e. the deadline tiebreak
/// decided (metric feed).
fn deadline_tiebreak_decided(loads: &[EngineLoad], dead: &[bool], chosen: usize) -> bool {
    let is_dead = |i: usize| dead.get(i).copied().unwrap_or(false);
    let plain = loads
        .iter()
        .enumerate()
        .filter(|(i, _)| !is_dead(*i))
        .min_by_key(|(_, l)| (l.rows, l.calls))
        .map(|(i, _)| i)
        .unwrap_or(chosen);
    chosen != plain
}

/// Builds the (reply-channel-bearing) message for one submission
/// attempt. Pool-routed submissions carry one of these instead of a
/// ready-made [`EngineMsg`] so a failed attempt can be rebuilt against
/// a fresh reply channel and re-placed on a live engine.
pub(crate) type MsgFactory<T> = Box<dyn Fn(Sender<Result<T>>) -> EngineMsg + Send>;

/// One engine's routing endpoint inside the router.
struct Slot {
    /// Mutex so the shared router stays `Sync` regardless of the
    /// `Sender` `Sync`-ness of the toolchain; submissions are rare
    /// relative to device work, so contention is irrelevant.
    tx: Mutex<Sender<EngineMsg>>,
    metrics: Arc<EngineMetrics>,
}

/// Shared routing state behind pool-backed [`EngineHandle`]s.
pub struct PoolRouter {
    slots: Vec<Slot>,
    loads: Mutex<Vec<EngineLoad>>,
    /// Health mask: `dead[i]` set once engine `i` stops accepting work.
    dead: Vec<AtomicBool>,
    pub metrics: PoolMetrics,
    /// The pool-shared cross-request cache tier (`None` when disabled);
    /// held here so the pool report can include its counters.
    cache: Option<Arc<EngineCache>>,
}

impl PoolRouter {
    pub fn engines(&self) -> usize {
        self.slots.len()
    }

    fn dead_snapshot(&self) -> Vec<bool> {
        self.dead.iter().map(|d| d.load(Ordering::SeqCst)).collect()
    }

    /// Number of engines still accepting work.
    pub fn live_engines(&self) -> usize {
        self.dead
            .iter()
            .filter(|d| !d.load(Ordering::SeqCst))
            .count()
    }

    /// Declare engine `idx` dead (idempotent; first caller logs and
    /// counts it). Dead engines take no further placements.
    pub(crate) fn mark_dead(&self, idx: usize, op: &str, why: &str) {
        if !self.dead[idx].swap(true, Ordering::SeqCst) {
            self.metrics.engines_marked_dead.inc();
            crate::log_warn!(
                "pool engine #{idx} (of {}) marked dead during {op}: {why}; \
                 {} engine(s) remain",
                self.slots.len(),
                self.live_engines()
            );
        }
    }

    /// The lowest-index live engine (control-plane ops anchor there).
    pub(crate) fn first_live(&self, op: &'static str) -> Result<usize> {
        (0..self.slots.len())
            .find(|&i| !self.dead[i].load(Ordering::SeqCst))
            .ok_or_else(|| Self::all_down(self.slots.len(), op))
    }

    /// Place and send one accounted submission, re-placing onto live
    /// engines as dead ones are discovered. Returns the reply channel
    /// and the guard that releases the reservation when the reply is
    /// harvested/dropped. Fails only when every engine is down.
    pub(crate) fn submit_with<T>(
        self: &Arc<Self>,
        make_msg: &MsgFactory<T>,
        rows: usize,
        deadline_ms: f64,
        op: &'static str,
    ) -> Result<(Receiver<Result<T>>, PoolGuard)> {
        let mut attempts = 0usize;
        loop {
            let idx = {
                let mut loads = self.loads.lock().unwrap();
                let dead = self.dead_snapshot();
                let Some(idx) = place_live(&loads, &dead) else {
                    return Err(Self::all_down(self.slots.len(), op));
                };
                if deadline_tiebreak_decided(&loads, &dead, idx) {
                    self.metrics.deadline_tiebreaks.inc();
                }
                loads[idx].rows += rows;
                loads[idx].calls += 1;
                loads[idx].deadlines.push(deadline_ms);
                idx
            };
            self.metrics.placements.inc();
            self.metrics.engine(idx).submits.inc();
            self.metrics.engine(idx).rows_submitted.add(rows as u64);
            let (reply, rx) = channel();
            let sent = { self.slots[idx].tx.lock().unwrap().send(make_msg(reply)) };
            match sent {
                Ok(()) => {
                    if attempts > 0 {
                        // Rescued: the submission survived ≥1 dead
                        // engine by landing on a live one.
                        self.metrics.rerouted_submits.inc();
                    }
                    return Ok((
                        rx,
                        PoolGuard {
                            router: self.clone(),
                            engine: idx,
                            rows,
                            deadline_ms,
                        },
                    ));
                }
                Err(_) => {
                    self.release(idx, rows, deadline_ms);
                    self.metrics.engine(idx).rejected_submits.inc();
                    self.mark_dead(idx, op, "submission channel closed");
                    attempts += 1;
                }
            }
        }
    }

    /// Send a control-plane message to a specific engine (no load
    /// accounting — probe train/load, info). A failed send marks the
    /// engine dead; the caller decides whether to retry elsewhere.
    pub(crate) fn send_to(&self, idx: usize, msg: EngineMsg, op: &'static str) -> Result<()> {
        let sent = { self.slots[idx].tx.lock().unwrap().send(msg) };
        sent.map_err(|_| {
            self.mark_dead(idx, op, "submission channel closed");
            Self::engine_down(idx, self.slots.len(), op)
        })
    }

    /// Install probe params on every live engine except `except`
    /// (the engine that just trained them holds them already) —
    /// replicas must answer probe queries identically no matter where a
    /// request lands. Engines that fail mid-broadcast are marked dead
    /// and skipped; the call fails only if a live engine *reports* an
    /// error, or if nobody is left to receive the params.
    pub(crate) fn broadcast_probe_load(
        &self,
        params: Vec<f32>,
        except: Option<usize>,
    ) -> Result<()> {
        let mut replies = Vec::new();
        for idx in 0..self.slots.len() {
            if Some(idx) == except || self.dead[idx].load(Ordering::SeqCst) {
                continue;
            }
            let (reply, rx) = channel();
            if self
                .send_to(
                    idx,
                    EngineMsg::ProbeLoad {
                        params: params.clone(),
                        reply,
                    },
                    "probe_load",
                )
                .is_err()
            {
                continue; // marked dead by send_to
            }
            replies.push((idx, rx));
        }
        let mut loaded = replies.len();
        for (idx, rx) in replies {
            match rx.recv() {
                Ok(r) => r?, // engine-side error: propagate
                Err(_) => {
                    self.mark_dead(idx, "probe_load", "reply channel dropped");
                    loaded -= 1;
                }
            }
        }
        // `except` already holds the params (it trained them), so a
        // broadcast from a trainer succeeds even if it is the last
        // engine standing.
        if loaded == 0 && except.is_none() {
            return Err(Self::all_down(self.slots.len(), "probe_load"));
        }
        Ok(())
    }

    fn engine_down(idx: usize, n: usize, op: &'static str) -> Error {
        Error::Engine(format!(
            "pool engine #{idx} (of {n}) is shut down — {op} submission rejected"
        ))
    }

    fn all_down(n: usize, op: &'static str) -> Error {
        Error::Engine(format!(
            "all {n} pool engines are down — {op} submission rejected"
        ))
    }

    /// Release one submission's reservation (reply harvested or
    /// dropped).
    fn release(&self, idx: usize, rows: usize, deadline_ms: f64) {
        let mut loads = self.loads.lock().unwrap();
        let l = &mut loads[idx];
        l.rows = l.rows.saturating_sub(rows);
        l.calls = l.calls.saturating_sub(1);
        if let Some(pos) = l
            .deadlines
            .iter()
            .position(|d| d.to_bits() == deadline_ms.to_bits())
        {
            l.deadlines.swap_remove(pos);
        }
        self.metrics.engine(idx).rows_completed.add(rows as u64);
    }

    /// Placement + per-engine utilization as JSON (embedded in `info()`
    /// and the serve report).
    pub fn report(&self) -> Value {
        let engines: Vec<&Arc<EngineMetrics>> = self.slots.iter().map(|s| &s.metrics).collect();
        build_report(
            &engines,
            Some(&self.metrics),
            Some(&self.dead_snapshot()),
            self.cache.as_deref(),
        )
    }
}

/// One report builder for every pool size, so a consumer written
/// against the N-engine shape never sees different keys from a pool
/// that happens to be size 1 (placement counters simply read 0 there).
fn build_report(
    engines: &[&Arc<EngineMetrics>],
    pool: Option<&PoolMetrics>,
    dead: Option<&[bool]>,
    cache: Option<&EngineCache>,
) -> Value {
    let is_dead = |i: usize| dead.and_then(|d| d.get(i)).copied().unwrap_or(false);
    let mut per_engine = Vec::with_capacity(engines.len());
    let mut served: Vec<u64> = Vec::with_capacity(engines.len());
    for (i, m) in engines.iter().enumerate() {
        served.push(m.rows_served());
        let routing = pool.map(|p| p.engine(i));
        per_engine.push(
            Value::obj()
                .with("engine", i)
                .with("dead", is_dead(i))
                .with("submits", routing.map_or(0, |r| r.submits.get()))
                .with("rows_submitted", routing.map_or(0, |r| r.rows_submitted.get()))
                .with("rows_completed", routing.map_or(0, |r| r.rows_completed.get()))
                .with("rejected_submits", routing.map_or(0, |r| r.rejected_submits.get()))
                .with("rows_served", m.rows_served())
                .with("decode_rows", m.decode_rows.get())
                .with("prm_rows", m.prm_rows.get())
                .with("embed_rows", m.embed_rows.get())
                .with("preempted_rows", m.preempted_rows.get())
                .with("tokens_generated", m.tokens_generated.get())
                .with("slot_occupancy", m.slot_occupancy())
                .with("decode_steps_saved_live", m.decode_steps_saved_live.get())
                .with("mid_decode_admits", m.mid_decode_admits.get())
                .with("retired_rows", m.retired_rows.get()),
        );
    }
    let total: u64 = served.iter().sum();
    let live = engines.len() - (0..engines.len()).filter(|&i| is_dead(i)).count();
    let mut v = Value::obj()
        .with("engines", engines.len())
        .with("live_engines", live)
        .with("placements", pool.map_or(0, |p| p.placements.get()))
        .with(
            "deadline_tiebreaks",
            pool.map_or(0, |p| p.deadline_tiebreaks.get()),
        )
        .with("rerouted_submits", pool.map_or(0, |p| p.rerouted_submits.get()))
        .with(
            "engines_marked_dead",
            pool.map_or(0, |p| p.engines_marked_dead.get()),
        )
        .with("balance_ratio", balance_ratio(&served))
        .with("rows_served_total", total)
        .with("per_engine", Value::Arr(per_engine));
    // the cache section appears only when the tier is enabled, so
    // consumers of the historical report shape see no new keys by
    // default
    if let Some(c) = cache {
        v.set("cache", c.to_json());
    }
    v
}

fn balance_ratio(served: &[u64]) -> f64 {
    let max = served.iter().copied().max().unwrap_or(0);
    let min = served.iter().copied().min().unwrap_or(0);
    max.max(1) as f64 / min.max(1) as f64
}

/// Releases one pool submission's placement accounting on drop; the
/// reply plumbing settles it as soon as the result is received.
pub struct PoolGuard {
    router: Arc<PoolRouter>,
    engine: usize,
    rows: usize,
    deadline_ms: f64,
}

impl PoolGuard {
    /// The engine this submission was placed on (failover needs to know
    /// whom to blame).
    pub(crate) fn engine(&self) -> usize {
        self.engine
    }
}

impl Drop for PoolGuard {
    fn drop(&mut self) {
        self.router.release(self.engine, self.rows, self.deadline_ms);
    }
}

/// A cloneable, read-only metrics view over a pool — what an engine
/// server hands its connection threads so the `metrics` op can answer
/// without owning (or keeping alive) the pool itself.
#[derive(Clone)]
pub struct PoolReporter {
    engines: Vec<Arc<EngineMetrics>>,
    router: Option<Arc<PoolRouter>>,
    cache: Option<Arc<EngineCache>>,
}

impl PoolReporter {
    /// Same shape as [`EnginePool::report`].
    pub fn report(&self) -> Value {
        match &self.router {
            Some(router) => router.report(),
            None => {
                let engines: Vec<&Arc<EngineMetrics>> = self.engines.iter().collect();
                build_report(&engines, None, None, self.cache.as_deref())
            }
        }
    }
}

/// Owns N engines plus the router that places work across them.
pub struct EnginePool {
    engines: Vec<Engine>,
    router: Option<Arc<PoolRouter>>,
    /// The cross-request cache tier shared by every engine of this pool
    /// (`None` when `engine.cache.enabled` is off).
    cache: Option<Arc<EngineCache>>,
    pub clock: SharedClock,
}

impl EnginePool {
    /// Spawn `cfg.engine.engines` engines (min 1) sharing one clock.
    /// With one engine the pool hands out a plain single-engine handle —
    /// the placement layer is bypassed entirely, so the pool-size-1 path
    /// is bit-for-bit the historical single-engine path.
    pub fn start(cfg: &Config) -> Result<EnginePool> {
        let clock: SharedClock = if cfg.engine.sim_clock {
            clock::sim_clock()
        } else {
            clock::real_clock()
        };
        Self::start_with_clock(cfg, clock)
    }

    pub fn start_with_clock(cfg: &Config, clock: SharedClock) -> Result<EnginePool> {
        // Remote pools share one multiplexed connection per distinct
        // host instead of dialing a socket per slot: build the per-host
        // transports once, then hand each slot its host's Arc.
        if matches!(cfg.engine.backend, crate::config::BackendKind::Remote) {
            let transports = crate::net::MuxTransport::per_host(&cfg.engine)?;
            let slot_clock = clock.clone();
            return Self::start_with_factories(cfg, clock, "remote backend", move |i| {
                crate::net::RemoteBackend::mux_factory(
                    transports[i % transports.len()].clone(),
                    slot_clock.clone(),
                )
            });
        }
        let n = cfg.engine.engines.max(1);
        // one cache for the whole pool: a stem decoded (or a prefix
        // scored) on any engine is a hit on every other
        let cache = EngineCache::from_config(&cfg.engine.cache);
        let mut engines = Vec::with_capacity(n);
        for i in 0..n {
            engines.push(Engine::start_member(cfg, clock.clone(), i, cache.clone())?);
        }
        Ok(Self::assemble(engines, clock, cache))
    }

    /// Spawn a pool whose engines run caller-supplied backends —
    /// `make(i)` builds the factory for pool slot `i`. This is how a
    /// remote pool is stood up over explicit
    /// [`crate::net::RemoteBackend`] connectors in tests and benches;
    /// the CLI path goes through the `BackendKind::Remote` config
    /// instead.
    pub fn start_with_factories(
        cfg: &Config,
        clock: SharedClock,
        label: &str,
        mut make: impl FnMut(usize) -> BackendFactory,
    ) -> Result<EnginePool> {
        let n = cfg.engine.engines.max(1);
        let cache = EngineCache::from_config(&cfg.engine.cache);
        let mut engines = Vec::with_capacity(n);
        for i in 0..n {
            engines.push(Engine::start_member_with_factory(
                clock.clone(),
                i,
                make(i),
                label,
                cache.clone(),
                cfg.engine.continuous,
            )?);
        }
        Ok(Self::assemble(engines, clock, cache))
    }

    fn assemble(
        engines: Vec<Engine>,
        clock: SharedClock,
        cache: Option<Arc<EngineCache>>,
    ) -> EnginePool {
        let n = engines.len();
        let router = if n > 1 {
            Some(Arc::new(PoolRouter {
                slots: engines
                    .iter()
                    .map(|e| Slot {
                        tx: Mutex::new(e.sender()),
                        metrics: e.metrics.clone(),
                    })
                    .collect(),
                loads: Mutex::new(vec![EngineLoad::default(); n]),
                dead: (0..n).map(|_| AtomicBool::new(false)).collect(),
                metrics: PoolMetrics::new(n),
                cache: cache.clone(),
            }))
        } else {
            None
        };
        EnginePool {
            engines,
            router,
            cache,
            clock,
        }
    }

    /// The pool-shared cross-request cache tier (`None` when disabled).
    pub fn cache(&self) -> Option<&Arc<EngineCache>> {
        self.cache.as_ref()
    }

    pub fn engines(&self) -> usize {
        self.engines.len()
    }

    /// The client handle: single-engine for a pool of 1, placement-
    /// routed otherwise.
    pub fn handle(&self) -> EngineHandle {
        match &self.router {
            None => self.engines[0].handle(),
            Some(router) => EngineHandle::pooled(router.clone()),
        }
    }

    /// Per-engine metrics (engine `i`).
    pub fn engine_metrics(&self, i: usize) -> &Arc<EngineMetrics> {
        &self.engines[i].metrics
    }

    /// Shut engine `i` down *now*, leaving the rest of the pool
    /// serving — fault injection for failover tests and benches. The
    /// router discovers the death on the next submission and reroutes.
    pub fn kill_engine(&mut self, i: usize) {
        self.engines[i].shutdown_now();
    }

    /// max/min rows served across the pool's engines.
    pub fn balance_ratio(&self) -> f64 {
        let served: Vec<u64> = self.engines.iter().map(|e| e.metrics.rows_served()).collect();
        balance_ratio(&served)
    }

    /// A cloneable metrics view (for engine servers' `metrics` op).
    pub fn reporter(&self) -> PoolReporter {
        PoolReporter {
            engines: self.engines.iter().map(|e| e.metrics.clone()).collect(),
            router: self.router.clone(),
            cache: self.cache.clone(),
        }
    }

    /// The pool report (placement counters + per-engine utilization);
    /// available even for a pool of 1 (same shape, placement counters
    /// read 0 because the single-engine handle bypasses the router).
    pub fn report(&self) -> Value {
        match &self.router {
            Some(router) => router.report(),
            None => {
                let engines: Vec<&Arc<EngineMetrics>> =
                    self.engines.iter().map(|e| &e.metrics).collect();
                build_report(&engines, None, None, self.cache.as_deref())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, gen_vec, prop_assert};

    fn load(rows: usize, calls: usize, deadlines: &[f64]) -> EngineLoad {
        EngineLoad {
            rows,
            calls,
            deadlines: deadlines.to_vec(),
        }
    }

    #[test]
    fn place_prefers_least_rows_then_calls_then_index() {
        let loads = vec![load(4, 1, &[]), load(2, 3, &[]), load(2, 1, &[])];
        assert_eq!(place(&loads), 2);
        let tie = vec![load(2, 1, &[]), load(2, 1, &[])];
        assert_eq!(place(&tie), 0, "full tie keeps the lowest index");
    }

    #[test]
    fn place_edf_tiebreak_avoids_urgent_backlogs() {
        // engines tied on rows/calls; #0 is racing a 100ms deadline,
        // #1's outstanding work is unconstrained → new work goes to #1
        let loads = vec![
            load(4, 1, &[100.0]),
            load(4, 1, &[f64::INFINITY]),
        ];
        assert_eq!(place(&loads), 1);
        // and between two constrained engines, the later deadline wins
        let loads = vec![load(4, 1, &[100.0]), load(4, 1, &[900.0])];
        assert_eq!(place(&loads), 1);
    }

    #[test]
    fn place_live_excludes_dead_engines() {
        let loads = vec![load(0, 0, &[]), load(9, 9, &[]), load(5, 5, &[])];
        // the least-loaded engine is dead → next-best live engine wins
        assert_eq!(place_live(&loads, &[true, false, false]), Some(2));
        assert_eq!(place_live(&loads, &[true, false, true]), Some(1));
        assert_eq!(place_live(&loads, &[true, true, true]), None);
        // a short mask means the tail is live
        assert_eq!(place_live(&loads, &[true]), Some(2));
        assert_eq!(place_live(&[], &[]), None);
    }

    #[test]
    fn min_deadline_of_empty_is_infinite() {
        assert_eq!(load(0, 0, &[]).min_deadline(), f64::INFINITY);
        assert_eq!(load(0, 0, &[7.0, 3.0]).min_deadline(), 3.0);
    }

    /// Random arrival/completion interleavings against a model: every
    /// job lands on exactly one engine, placement always picks a
    /// least-loaded engine (by rows) at decision time, and the
    /// accounting returns to zero once everything completes.
    #[test]
    fn prop_placement_least_loaded_and_conserving() {
        forall(
            "pool placement invariants",
            150,
            |rng| {
                let engines = rng.range(1, 5) as usize;
                let events = gen_vec(rng, 1..40, |r| {
                    // (arrival? , rows, deadline-bucket)
                    (
                        r.below(3) < 2, // 2/3 arrivals, 1/3 completions
                        r.range(1, 9) as usize,
                        r.below(4),
                    )
                });
                (engines, events)
            },
            |(engines, events)| {
                let mut loads = vec![EngineLoad::default(); *engines];
                // outstanding jobs: (engine, rows, deadline)
                let mut outstanding: Vec<(usize, usize, f64)> = Vec::new();
                let mut placed = 0usize;
                for &(arrive, rows, dbucket) in events {
                    if arrive {
                        let deadline = match dbucket {
                            0 => 100.0,
                            1 => 1000.0,
                            2 => 10_000.0,
                            _ => f64::INFINITY,
                        };
                        let idx = place(&loads);
                        prop_assert(idx < *engines, "placement out of range".to_string())?;
                        let min_rows = loads.iter().map(|l| l.rows).min().unwrap();
                        prop_assert(
                            loads[idx].rows == min_rows,
                            format!(
                                "picked engine {idx} with {} rows, min is {min_rows}",
                                loads[idx].rows
                            ),
                        )?;
                        loads[idx].rows += rows;
                        loads[idx].calls += 1;
                        loads[idx].deadlines.push(deadline);
                        outstanding.push((idx, rows, deadline));
                        placed += 1;
                    } else if !outstanding.is_empty() {
                        // complete the oldest outstanding job
                        let (idx, rows, deadline) = outstanding.remove(0);
                        let l = &mut loads[idx];
                        l.rows -= rows;
                        l.calls -= 1;
                        let pos = l
                            .deadlines
                            .iter()
                            .position(|d| d.to_bits() == deadline.to_bits())
                            .expect("deadline tracked");
                        l.deadlines.swap_remove(pos);
                    }
                }
                // drain the rest; accounting must conserve exactly
                for (idx, rows, deadline) in outstanding.drain(..) {
                    let l = &mut loads[idx];
                    l.rows -= rows;
                    l.calls -= 1;
                    let pos = l
                        .deadlines
                        .iter()
                        .position(|d| d.to_bits() == deadline.to_bits())
                        .expect("deadline tracked");
                    l.deadlines.swap_remove(pos);
                }
                for (i, l) in loads.iter().enumerate() {
                    prop_assert(
                        l.rows == 0 && l.calls == 0 && l.deadlines.is_empty(),
                        format!("engine {i} accounting leaked: {l:?}"),
                    )?;
                }
                prop_assert(placed <= events.len(), "jobs placed once each".to_string())
            },
        );
    }

    /// Placement with a random liveness mask never lands on a dead
    /// engine, and agrees with [`place`] when everyone is live.
    #[test]
    fn prop_place_live_respects_the_mask() {
        forall(
            "place_live respects liveness",
            200,
            |rng| {
                let n = rng.range(1, 6) as usize;
                let loads: Vec<EngineLoad> = (0..n)
                    .map(|_| EngineLoad {
                        rows: rng.below(10) as usize,
                        calls: rng.below(5) as usize,
                        deadlines: Vec::new(),
                    })
                    .collect();
                let dead: Vec<bool> = (0..n).map(|_| rng.below(3) == 0).collect();
                (loads, dead)
            },
            |(loads, dead)| {
                match place_live(loads, dead) {
                    Some(idx) => {
                        prop_assert(idx < loads.len(), "index in range".to_string())?;
                        prop_assert(!dead[idx], format!("picked dead engine {idx}"))?;
                        let min_live = loads
                            .iter()
                            .enumerate()
                            .filter(|(i, _)| !dead[*i])
                            .map(|(_, l)| l.rows)
                            .min()
                            .unwrap();
                        prop_assert(
                            loads[idx].rows == min_live,
                            "picked a non-least-loaded live engine".to_string(),
                        )?;
                    }
                    None => {
                        prop_assert(
                            dead.iter().all(|&d| d),
                            "returned None with live engines remaining".to_string(),
                        )?;
                    }
                }
                if dead.iter().all(|&d| !d) && !loads.is_empty() {
                    prop_assert(
                        place_live(loads, dead) == Some(place(loads)),
                        "all-live placement must match place()".to_string(),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn submissions_reroute_around_a_killed_engine() {
        use crate::config::BackendKind;
        let mut cfg = Config::default();
        cfg.engine.backend = BackendKind::Sim;
        cfg.engine.sim_clock = true;
        cfg.engine.engines = 2;
        let mut pool = EnginePool::start(&cfg).unwrap();
        let handle = pool.handle();
        let before = handle.prm_score(vec![vec![1u32, 2, 3]]).unwrap();

        pool.kill_engine(0);
        for _ in 0..4 {
            // least-loaded placement keeps trying the idle dead engine
            // first; every request must still succeed on the live one
            let after = handle.prm_score(vec![vec![1u32, 2, 3]]).unwrap();
            assert_eq!(before, after, "reroute must not change results");
        }
        let report = pool.report();
        assert!(report.req_f64("rerouted_submits").unwrap() >= 1.0);
        assert_eq!(report.req_f64("engines_marked_dead").unwrap(), 1.0);
        assert_eq!(report.req_f64("live_engines").unwrap(), 1.0);
        let per = report.req_arr("per_engine").unwrap();
        assert_eq!(per[0].req("dead").unwrap().as_bool(), Some(true));

        pool.kill_engine(1);
        let err = handle
            .prm_score(vec![vec![1u32, 2, 3]])
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("all 2 pool engines are down") && err.contains("prm_score"),
            "all-down error should be descriptive: {err}"
        );
    }

    #[test]
    fn balance_ratio_clamps_zero_servers() {
        assert_eq!(balance_ratio(&[10, 10]), 1.0);
        assert_eq!(balance_ratio(&[20, 10]), 2.0);
        assert_eq!(balance_ratio(&[10, 0]), 10.0);
        assert_eq!(balance_ratio(&[]), 1.0);
    }
}
