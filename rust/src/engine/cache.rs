//! Cross-request cache tier: prefix-trie generation reuse + a sharded
//! PRM/embed score cache, shared by every engine of a pool.
//!
//! The cache sits *behind* the engine thread, in front of the
//! [`crate::engine::backend::Backend`]: sim, device and remote paths
//! all consult it before planning a call, so a `RemoteBackend` client
//! fills it from remote replies exactly like a local backend does.
//! [`docs/caching.md`](../../../docs/caching.md) is the full contract;
//! the short version:
//!
//! * **Generation** entries live in a per-shard *prefix trie* keyed on
//!   token stems (one trie walk per prompt, entries at exact stem
//!   depth), so the beam family's chained prompts — each round's prompt
//!   extends the previous round's — share stem storage instead of
//!   duplicating it. A hit requires the *exact* prompt at temperature 0
//!   for the same [`GenKind`]: the `Backend` contract guarantees temp-0
//!   purity per prompt, **not** that a longer prompt's output extends a
//!   shorter one's, so stem-extension reuse would silently change
//!   results (the sim backend re-parses chunk boundaries, for one).
//!   The cached value is the row's *natural* (pre-budget-cut) output;
//!   budget/deadline cuts replay per request in
//!   [`crate::engine::preempt::cut_replayed_row`] without charging the
//!   clock, which is where `decode_steps_saved` comes from.
//! * **Scores** (PRM + both embed kinds, pure at any temperature) live
//!   in a sharded size-bounded map consulted before bin-packing, so
//!   cached rows are subtracted from the batch plan entirely.
//! * Both stores use per-shard locks (the coalescing scheduler never
//!   serializes on one global lock), exact per-shard LRU eviction, and
//!   a probe-generation stamp: `probe_load` / `probe_train` bump the
//!   generation and clear the shards, and inserts stamped with an
//!   older generation are dropped (a backend call that raced a probe
//!   swap cannot resurrect pre-swap scores).
//!
//! `max_entries` bounds the generation store and the score store
//! independently (each is split over `shards` shards of
//! `max_entries / shards` slots).

use crate::config::CacheConfig;
use crate::engine::protocol::{EmbedKind, GenKind};
use crate::metrics::CacheMetrics;
use crate::util::json::Value;
use std::collections::hash_map::DefaultHasher;
use std::collections::{BTreeMap, HashMap};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// How many leading prompt tokens pick a generation shard: stems that
/// agree on their first tokens land on the same shard, so a chain of
/// extending prompts shares one trie.
const GEN_SHARD_STEM: usize = 8;

/// Key of one cached score row.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum ScoreKey {
    /// PRM score of a prefix, pre-truncated to `prm_len` by the caller
    /// (both backends score only the first `prm_len` tokens, so longer
    /// prefixes sharing that window share the entry).
    Prm(Vec<u32>),
    /// Embedding of a full query for one [`EmbedKind`].
    Embed(EmbedKind, Vec<u32>),
}

/// One cached score row.
#[derive(Debug, Clone, PartialEq)]
pub enum ScoreValue {
    Prm(f32),
    Embed(Vec<f32>),
}

fn hash64(h: &impl Hash) -> u64 {
    let mut s = DefaultHasher::new();
    h.hash(&mut s);
    s.finish()
}

// ---------------------------------------------------------------------
// generation store: per-shard prefix trie with exact LRU
// ---------------------------------------------------------------------

const NO_NODE: u32 = u32::MAX;

struct GenEntry {
    /// The row's natural (pre-budget-cut) output tokens.
    natural: Vec<u32>,
    /// Probe generation the producing backend call observed.
    probe_gen: u64,
    /// Current LRU stamp (key into `GenShard::lru`).
    seq: u64,
}

struct GenNode {
    token: u32,
    parent: u32,
    children: HashMap<u32, u32>,
    entry: Option<GenEntry>,
}

impl GenNode {
    fn new(token: u32, parent: u32) -> GenNode {
        GenNode {
            token,
            parent,
            children: HashMap::new(),
            entry: None,
        }
    }
}

/// One generation shard: an arena-backed trie (two roots, one per
/// [`GenKind`]) plus an LRU index over the nodes that hold entries.
struct GenShard {
    nodes: Vec<GenNode>,
    free: Vec<u32>,
    /// LRU order: seq -> node index (oldest first).
    lru: BTreeMap<u64, u32>,
    seq: u64,
    entries: usize,
    cap: usize,
}

impl GenShard {
    fn new(cap: usize) -> GenShard {
        GenShard {
            // nodes[0] / nodes[1]: Full / Chunk roots
            nodes: vec![GenNode::new(0, NO_NODE), GenNode::new(0, NO_NODE)],
            free: Vec::new(),
            lru: BTreeMap::new(),
            seq: 0,
            entries: 0,
            cap,
        }
    }

    fn root(kind: GenKind) -> u32 {
        match kind {
            GenKind::Full => 0,
            GenKind::Chunk => 1,
        }
    }

    /// Walk the trie to the node at exact stem depth, if present.
    fn find(&self, kind: GenKind, prompt: &[u32]) -> Option<u32> {
        let mut at = Self::root(kind);
        for &t in prompt {
            at = *self.nodes[at as usize].children.get(&t)?;
        }
        Some(at)
    }

    fn touch(&mut self, node: u32) -> u64 {
        self.seq += 1;
        let seq = self.seq;
        if let Some(e) = self.nodes[node as usize].entry.as_mut() {
            self.lru.remove(&e.seq);
            e.seq = seq;
        }
        self.lru.insert(seq, node);
        seq
    }

    fn lookup(&mut self, kind: GenKind, prompt: &[u32], current_gen: u64) -> Option<Vec<u32>> {
        let node = self.find(kind, prompt)?;
        let fresh = match self.nodes[node as usize].entry {
            Some(ref e) if e.probe_gen == current_gen => Some(e.natural.clone()),
            Some(_) => None, // stale (pre-probe-swap): drop it lazily
            None => return None,
        };
        match fresh {
            Some(natural) => {
                self.touch(node);
                Some(natural)
            }
            None => {
                self.remove_entry(node);
                None
            }
        }
    }

    fn insert(&mut self, kind: GenKind, prompt: &[u32], natural: &[u32], probe_gen: u64) -> u64 {
        let mut at = Self::root(kind);
        for &t in prompt {
            at = match self.nodes[at as usize].children.get(&t) {
                Some(&c) => c,
                None => {
                    let idx = match self.free.pop() {
                        Some(idx) => {
                            self.nodes[idx as usize] = GenNode::new(t, at);
                            idx
                        }
                        None => {
                            self.nodes.push(GenNode::new(t, at));
                            (self.nodes.len() - 1) as u32
                        }
                    };
                    self.nodes[at as usize].children.insert(t, idx);
                    idx
                }
            };
        }
        if self.nodes[at as usize].entry.is_none() {
            self.entries += 1;
        } else if let Some(e) = self.nodes[at as usize].entry.take() {
            self.lru.remove(&e.seq);
        }
        self.seq += 1;
        let seq = self.seq;
        self.nodes[at as usize].entry = Some(GenEntry {
            natural: natural.to_vec(),
            probe_gen,
            seq,
        });
        self.lru.insert(seq, at);

        let mut evicted = 0u64;
        while self.entries > self.cap {
            if let Some((&oldest, &victim)) = self.lru.iter().next() {
                debug_assert_ne!(victim, at, "just-inserted entry evicted (cap 0?)");
                self.lru.remove(&oldest);
                self.drop_entry_and_prune(victim);
                evicted += 1;
            } else {
                break;
            }
        }
        evicted
    }

    /// Remove a node's entry (including its LRU stamp) and prune the
    /// now-useless leaf chain back toward the root.
    fn remove_entry(&mut self, node: u32) {
        if let Some(e) = self.nodes[node as usize].entry.take() {
            self.lru.remove(&e.seq);
            self.entries -= 1;
        }
        self.prune(node);
    }

    /// As [`remove_entry`], for entries whose LRU stamp the caller
    /// already removed.
    fn drop_entry_and_prune(&mut self, node: u32) {
        if self.nodes[node as usize].entry.take().is_some() {
            self.entries -= 1;
        }
        self.prune(node);
    }

    fn prune(&mut self, mut node: u32) {
        while node != NO_NODE {
            let n = &self.nodes[node as usize];
            if n.parent == NO_NODE || n.entry.is_some() || !n.children.is_empty() {
                break;
            }
            let (parent, token) = (n.parent, n.token);
            self.nodes[parent as usize].children.remove(&token);
            self.free.push(node);
            node = parent;
        }
    }

    fn clear(&mut self) {
        *self = GenShard::new(self.cap);
    }
}

// ---------------------------------------------------------------------
// score store: per-shard map with exact LRU
// ---------------------------------------------------------------------

struct ScoreSlot {
    value: ScoreValue,
    probe_gen: u64,
    seq: u64,
}

struct ScoreShard {
    map: HashMap<ScoreKey, ScoreSlot>,
    /// LRU order: seq -> key (oldest first).
    lru: BTreeMap<u64, ScoreKey>,
    seq: u64,
    cap: usize,
}

impl ScoreShard {
    fn new(cap: usize) -> ScoreShard {
        ScoreShard {
            map: HashMap::new(),
            lru: BTreeMap::new(),
            seq: 0,
            cap,
        }
    }

    fn lookup(&mut self, key: &ScoreKey, current_gen: u64) -> Option<ScoreValue> {
        let stale = match self.map.get(key) {
            Some(slot) if slot.probe_gen == current_gen => false,
            Some(_) => true,
            None => return None,
        };
        if stale {
            if let Some(slot) = self.map.remove(key) {
                self.lru.remove(&slot.seq);
            }
            return None;
        }
        self.seq += 1;
        let seq = self.seq;
        let slot = self.map.get_mut(key).unwrap();
        self.lru.remove(&slot.seq);
        slot.seq = seq;
        let value = slot.value.clone();
        self.lru.insert(seq, key.clone());
        Some(value)
    }

    fn insert(&mut self, key: ScoreKey, value: ScoreValue, probe_gen: u64) -> u64 {
        self.seq += 1;
        let seq = self.seq;
        if let Some(old) = self.map.insert(
            key.clone(),
            ScoreSlot {
                value,
                probe_gen,
                seq,
            },
        ) {
            self.lru.remove(&old.seq);
        }
        self.lru.insert(seq, key);

        let mut evicted = 0u64;
        while self.map.len() > self.cap {
            if let Some((&oldest, _)) = self.lru.iter().next() {
                if let Some(victim) = self.lru.remove(&oldest) {
                    self.map.remove(&victim);
                    evicted += 1;
                }
            } else {
                break;
            }
        }
        evicted
    }

    fn clear(&mut self) {
        self.map.clear();
        self.lru.clear();
    }
}

// ---------------------------------------------------------------------
// EngineCache
// ---------------------------------------------------------------------

/// The shared cross-request cache tier. One instance per
/// [`crate::engine::pool::EnginePool`] (every engine of a pool shares
/// it via `Arc`), or per single engine.
pub struct EngineCache {
    gen_shards: Vec<Mutex<GenShard>>,
    score_shards: Vec<Mutex<ScoreShard>>,
    /// Bumped by [`invalidate`](EngineCache::invalidate); entries and
    /// inserts stamped with an older generation are ignored.
    probe_gen: AtomicU64,
    pub metrics: CacheMetrics,
    max_entries: usize,
}

impl EngineCache {
    pub fn new(cfg: &CacheConfig) -> EngineCache {
        let shards = cfg.shards.max(1);
        let cap = (cfg.max_entries / shards).max(1);
        EngineCache {
            gen_shards: (0..shards).map(|_| Mutex::new(GenShard::new(cap))).collect(),
            score_shards: (0..shards)
                .map(|_| Mutex::new(ScoreShard::new(cap)))
                .collect(),
            probe_gen: AtomicU64::new(0),
            metrics: CacheMetrics::new(),
            max_entries: cap * shards,
        }
    }

    /// `Some(shared cache)` when the config enables it, else `None` —
    /// the disabled path carries no cache at all, so every engine code
    /// path stays byte-identical to the pre-cache engine.
    pub fn from_config(cfg: &CacheConfig) -> Option<Arc<EngineCache>> {
        if cfg.enabled {
            Some(Arc::new(EngineCache::new(cfg)))
        } else {
            None
        }
    }

    /// The current probe generation. Capture this *before* a backend
    /// call and pass it to the insert: an insert that raced a probe
    /// swap is then dropped instead of poisoning the post-swap cache.
    pub fn generation(&self) -> u64 {
        self.probe_gen.load(Ordering::Acquire)
    }

    /// Drop everything and start a new generation — hooked into
    /// `probe_load` / `probe_train`, whose parameter swaps change what
    /// the backends would answer.
    pub fn invalidate(&self) {
        self.probe_gen.fetch_add(1, Ordering::AcqRel);
        for s in &self.gen_shards {
            s.lock().unwrap().clear();
        }
        for s in &self.score_shards {
            s.lock().unwrap().clear();
        }
        self.metrics.invalidations.inc();
    }

    fn gen_shard(&self, kind: GenKind, prompt: &[u32]) -> &Mutex<GenShard> {
        let stem = &prompt[..prompt.len().min(GEN_SHARD_STEM)];
        let h = hash64(&(kind, stem));
        &self.gen_shards[(h % self.gen_shards.len() as u64) as usize]
    }

    fn score_shard(&self, key: &ScoreKey) -> &Mutex<ScoreShard> {
        let h = hash64(key);
        &self.score_shards[(h % self.score_shards.len() as u64) as usize]
    }

    /// Exact-prompt generation lookup (counts a hit or a miss). Only
    /// meaningful at temperature 0 — the caller gates on that.
    pub fn lookup_gen(&self, kind: GenKind, prompt: &[u32]) -> Option<Vec<u32>> {
        let gen = self.generation();
        let hit = self.gen_shard(kind, prompt).lock().unwrap().lookup(kind, prompt, gen);
        match hit {
            Some(natural) => {
                self.metrics.hits.inc();
                Some(natural)
            }
            None => {
                self.metrics.misses.inc();
                None
            }
        }
    }

    /// Insert a row's *natural* (pre-budget-cut) output, stamped with
    /// the generation captured before the producing backend call.
    pub fn insert_gen(&self, kind: GenKind, prompt: &[u32], natural: &[u32], gen: u64) {
        if gen != self.generation() {
            return; // raced a probe swap; drop
        }
        let evicted = self
            .gen_shard(kind, prompt)
            .lock()
            .unwrap()
            .insert(kind, prompt, natural, gen);
        self.metrics.evictions.add(evicted);
    }

    /// Score lookup (counts a hit or a miss). Pure at any temperature.
    pub fn lookup_score(&self, key: &ScoreKey) -> Option<ScoreValue> {
        let gen = self.generation();
        let hit = self.score_shard(key).lock().unwrap().lookup(key, gen);
        match hit {
            Some(v) => {
                self.metrics.hits.inc();
                Some(v)
            }
            None => {
                self.metrics.misses.inc();
                None
            }
        }
    }

    pub fn insert_score(&self, key: ScoreKey, value: ScoreValue, gen: u64) {
        if gen != self.generation() {
            return;
        }
        let evicted = self.score_shard(&key).lock().unwrap().insert(key, value, gen);
        self.metrics.evictions.add(evicted);
    }

    /// Current entry counts: `(generation store, score store)`.
    pub fn len(&self) -> (usize, usize) {
        let g = self.gen_shards.iter().map(|s| s.lock().unwrap().entries).sum();
        let s = self
            .score_shards
            .iter()
            .map(|s| s.lock().unwrap().map.len())
            .sum();
        (g, s)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == (0, 0)
    }

    /// Counters + configuration snapshot for `info()` / pool / serve
    /// reports.
    pub fn to_json(&self) -> Value {
        let (gen_entries, score_entries) = self.len();
        self.metrics
            .to_json()
            .with("max_entries", self.max_entries)
            .with("shards", self.gen_shards.len())
            .with("gen_entries", gen_entries)
            .with("score_entries", score_entries)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::backend::{Backend, SimBackend};
    use crate::testkit::{forall, gen_vec, prop_assert};

    fn cache(max_entries: usize, shards: usize) -> EngineCache {
        EngineCache::new(&CacheConfig {
            enabled: true,
            max_entries,
            shards,
        })
    }

    #[test]
    fn gen_roundtrip_and_kind_isolation() {
        let c = cache(64, 4);
        let g = c.generation();
        c.insert_gen(GenKind::Full, &[1, 2, 3], &[9, 8], g);
        assert_eq!(c.lookup_gen(GenKind::Full, &[1, 2, 3]), Some(vec![9, 8]));
        // same tokens, other kind: a different trie root
        assert_eq!(c.lookup_gen(GenKind::Chunk, &[1, 2, 3]), None);
        // stems are not entries: the prefix node exists but holds no row
        assert_eq!(c.lookup_gen(GenKind::Full, &[1, 2]), None);
        assert_eq!(c.len(), (1, 0));
    }

    #[test]
    fn shared_stems_share_trie_nodes() {
        let c = cache(64, 1);
        let g = c.generation();
        // a beam chain: each prompt extends the previous one
        c.insert_gen(GenKind::Chunk, &[5, 6, 7], &[1], g);
        c.insert_gen(GenKind::Chunk, &[5, 6, 7, 8], &[2], g);
        c.insert_gen(GenKind::Chunk, &[5, 6, 7, 8, 9], &[3], g);
        let shard = c.gen_shards[0].lock().unwrap();
        // 2 roots + 5 distinct tokens: extensions reuse the shared stem
        assert_eq!(shard.nodes.len() - shard.free.len(), 2 + 5);
        assert_eq!(shard.entries, 3);
    }

    #[test]
    fn lru_evicts_the_oldest_entry_and_prunes_its_chain() {
        let c = cache(2, 1);
        let g = c.generation();
        c.insert_gen(GenKind::Full, &[1, 1, 1], &[1], g);
        c.insert_gen(GenKind::Full, &[2], &[2], g);
        // touch [1,1,1] so [2] is now oldest
        assert!(c.lookup_gen(GenKind::Full, &[1, 1, 1]).is_some());
        c.insert_gen(GenKind::Full, &[3], &[3], g);
        assert_eq!(c.metrics.evictions.get(), 1);
        assert_eq!(c.lookup_gen(GenKind::Full, &[2]), None);
        assert!(c.lookup_gen(GenKind::Full, &[1, 1, 1]).is_some());
        assert!(c.lookup_gen(GenKind::Full, &[3]).is_some());
    }

    #[test]
    fn score_roundtrip_and_lru() {
        let c = cache(2, 1);
        let g = c.generation();
        c.insert_score(ScoreKey::Prm(vec![1]), ScoreValue::Prm(0.5), g);
        c.insert_score(
            ScoreKey::Embed(EmbedKind::Pool, vec![1]),
            ScoreValue::Embed(vec![1.0, 2.0]),
            g,
        );
        // PRM and embed keys don't collide even on equal tokens
        assert_eq!(
            c.lookup_score(&ScoreKey::Prm(vec![1])),
            Some(ScoreValue::Prm(0.5))
        );
        c.insert_score(ScoreKey::Prm(vec![2]), ScoreValue::Prm(0.7), g);
        // the embed row was oldest
        assert_eq!(
            c.lookup_score(&ScoreKey::Embed(EmbedKind::Pool, vec![1])),
            None
        );
        assert_eq!(c.len().1, 2);
    }

    #[test]
    fn invalidate_clears_and_drops_racing_inserts() {
        let c = cache(64, 4);
        let old = c.generation();
        c.insert_gen(GenKind::Full, &[1], &[1], old);
        c.insert_score(ScoreKey::Prm(vec![1]), ScoreValue::Prm(0.5), old);
        c.invalidate();
        assert!(c.is_empty());
        assert_eq!(c.lookup_gen(GenKind::Full, &[1]), None);
        assert_eq!(c.lookup_score(&ScoreKey::Prm(vec![1])), None);
        // inserts stamped with the pre-swap generation are dropped
        c.insert_gen(GenKind::Full, &[2], &[2], old);
        c.insert_score(ScoreKey::Prm(vec![2]), ScoreValue::Prm(0.9), old);
        assert!(c.is_empty());
        assert_eq!(c.metrics.invalidations.get(), 1);
    }

    // ---- properties ----

    #[test]
    fn prop_stores_never_exceed_max_entries() {
        forall(
            "cache stays within max_entries",
            120,
            |rng| {
                let max_entries = rng.range(1, 24) as usize;
                let shards = rng.range(1, 5) as usize;
                let ops = gen_vec(rng, 1..80, |r| {
                    let prompt: Vec<u32> = gen_vec(r, 1..6, |r2| r2.below(8) as u32);
                    (r.below(4), prompt)
                });
                (max_entries, shards, ops)
            },
            |(max_entries, shards, ops)| {
                let c = cache(*max_entries, *shards);
                let g = c.generation();
                // per-shard caps round down, so the effective global
                // bound is cap * shards (≤ max(max_entries, shards))
                let bound = (*max_entries / *shards).max(1) * *shards;
                for (op, prompt) in ops {
                    match *op {
                        0 => c.insert_gen(GenKind::Full, prompt, &[1, 2], g),
                        1 => c.insert_gen(GenKind::Chunk, prompt, &[3], g),
                        2 => c.insert_score(
                            ScoreKey::Prm(prompt.clone()),
                            ScoreValue::Prm(0.5),
                            g,
                        ),
                        _ => c.insert_score(
                            ScoreKey::Embed(EmbedKind::Small, prompt.clone()),
                            ScoreValue::Embed(vec![0.0]),
                            g,
                        ),
                    }
                    let (gen_n, score_n) = c.len();
                    prop_assert(
                        gen_n <= bound && score_n <= bound,
                        format!("({gen_n}, {score_n}) entries > bound {bound}"),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_hit_is_byte_identical_to_a_fresh_backend_call() {
        // The property the integration tier relies on: serving a score
        // or a temp-0 generation from the cache returns bit-for-bit
        // what calling the backend again would return.
        let mut backend = SimBackend::new(
            crate::engine::backend::EngineShapes::sim_default(&crate::config::EngineConfig::default()),
            crate::util::clock::sim_clock(),
            7,
            0,
        );
        let c = cache(4096, 8);
        forall(
            "cache hit == fresh backend call",
            60,
            |rng| gen_vec(rng, 1..12, |r| r.below(40) as u32 + 1),
            |prefix| {
                let g = c.generation();
                let fresh = backend.prm_score(1, &[prefix.clone()]).unwrap()[0];
                c.insert_score(ScoreKey::Prm(prefix.clone()), ScoreValue::Prm(fresh), g);
                let again = backend.prm_score(1, &[prefix.clone()]).unwrap()[0];
                let cached = match c.lookup_score(&ScoreKey::Prm(prefix.clone())) {
                    Some(ScoreValue::Prm(v)) => v,
                    other => return Err(format!("expected a PRM hit, got {other:?}")),
                };
                prop_assert(
                    cached.to_bits() == again.to_bits() && cached.to_bits() == fresh.to_bits(),
                    format!("cached {cached} != fresh {again}"),
                )
            },
        );
    }

    #[test]
    fn prop_probe_swap_invalidates_everything() {
        forall(
            "probe swap leaves no pre-swap entry reachable",
            60,
            |rng| {
                gen_vec(rng, 1..20, |r| {
                    gen_vec(r, 1..6, |r2| r2.below(10) as u32)
                })
            },
            |prompts| {
                let c = cache(1024, 4);
                let g = c.generation();
                for p in prompts {
                    c.insert_gen(GenKind::Full, p, &[7], g);
                    c.insert_score(ScoreKey::Prm(p.clone()), ScoreValue::Prm(0.25), g);
                }
                c.invalidate();
                for p in prompts {
                    prop_assert(
                        c.lookup_gen(GenKind::Full, p).is_none()
                            && c.lookup_score(&ScoreKey::Prm(p.clone())).is_none(),
                        format!("pre-swap entry for {p:?} survived invalidation"),
                    )?;
                }
                prop_assert(c.is_empty(), "stores not empty after invalidation")
            },
        );
    }
}
