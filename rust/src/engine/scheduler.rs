//! Coalescing scheduler: per-op queues over the engine channel.
//!
//! The engine thread serves one *round* at a time: when a message
//! arrives, every message already queued behind it is drained and sorted
//! into per-op queues ([`drain_round`]) so that `Generate`, `PrmScore`
//! and `Embed` requests from concurrent workers each merge into shared
//! bucket-shaped device calls — beam-family strategies alternate
//! generate → score, so under multi-worker load coalescing roughly
//! halves padded PRM rows versus serving each message's small batch in
//! its own padded call.
//!
//! This module is the *pure* half of the scheduler: classification,
//! request flattening and result scatter ([`flatten`] / [`scatter`]) are
//! all testable without PJRT, and the equivalence property — coalesced
//! execution returns exactly what serial per-message execution would —
//! is property-tested below against a mock executor. The device half
//! (actually running the coalesced calls) lives in
//! [`crate::engine::thread`]; call *ordering* within a round
//! (earliest-deadline-first) lives in [`crate::engine::batcher`].
//!
//! ## Ordering contract
//!
//! Workers block on their reply channel, so a single worker never has
//! two messages in flight — per-worker program order is preserved no
//! matter how a round reorders ops. Across workers the pre-scheduler
//! engine gave no ordering guarantee either (channel arrival order was
//! already a race); the round merely fixes the arbitrary interleaving
//! to: control-plane ops (probe, info) in arrival order, then coalesced
//! PRM scoring, then coalesced embeds, then generation plans in EDF
//! order. Scoring and embeds run first because they are short and
//! unblock workers to contribute generate jobs to the *next* round.

use crate::engine::protocol::{EmbedKind, EngineMsg, GenJob, GenResult};
use crate::error::Result;
use std::ops::Range;
use std::sync::mpsc::Sender;

/// One queued generation request: jobs, the request's absolute batch
/// deadline, and the reply channel its results go back on.
pub struct GenerateReq {
    pub jobs: Vec<GenJob>,
    pub deadline_ms: Option<f64>,
    pub reply: Sender<Result<Vec<GenResult>>>,
}

/// One queued PRM scoring request.
pub struct PrmReq {
    pub prefixes: Vec<Vec<u32>>,
    pub reply: Sender<Result<Vec<f32>>>,
}

/// One queued embedding request.
pub struct EmbedReq {
    pub kind: EmbedKind,
    pub queries: Vec<Vec<u32>>,
    pub reply: Sender<Result<Vec<Vec<f32>>>>,
}

/// One scheduling round: every message available on the channel at
/// drain time, sorted into per-op queues.
pub struct Round {
    pub generates: Vec<GenerateReq>,
    pub prm: Vec<PrmReq>,
    pub embeds: Vec<EmbedReq>,
    /// Control-plane messages (probe fwd/train/load, info), arrival order.
    pub others: Vec<EngineMsg>,
    /// A `Shutdown` was drained; the round still executes, then the
    /// serve loop exits.
    pub shutdown: bool,
}

impl Round {
    fn new() -> Round {
        Round {
            generates: Vec::new(),
            prm: Vec::new(),
            embeds: Vec::new(),
            others: Vec::new(),
            shutdown: false,
        }
    }

    /// Messages carried by this round (excluding `Shutdown`).
    pub fn len(&self) -> usize {
        self.generates.len() + self.prm.len() + self.embeds.len() + self.others.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Classify one message into its queue; returns `false` on
    /// `Shutdown` (drain stops so no post-shutdown work is accepted).
    fn push(&mut self, msg: EngineMsg) -> bool {
        match msg {
            EngineMsg::Generate {
                jobs,
                deadline_ms,
                reply,
            } => self.generates.push(GenerateReq {
                jobs,
                deadline_ms,
                reply,
            }),
            EngineMsg::PrmScore { prefixes, reply } => {
                self.prm.push(PrmReq { prefixes, reply })
            }
            EngineMsg::Embed {
                kind,
                queries,
                reply,
            } => self.embeds.push(EmbedReq {
                kind,
                queries,
                reply,
            }),
            EngineMsg::Shutdown => {
                self.shutdown = true;
                return false;
            }
            other => self.others.push(other),
        }
        true
    }
}

/// Most messages one round drains (`first` included). A sustained burst
/// of arrivals could otherwise keep the drain loop pulling forever and
/// starve the already-queued work's dispatch; past the cap the rest
/// simply waits for the next round.
pub const DRAIN_CAP: usize = 256;

/// Build one round: classify `first`, then keep pulling from `next`
/// (non-blocking, e.g. `|| rx.try_recv().ok()`) until the channel is
/// momentarily empty, [`DRAIN_CAP`] messages are in, or a `Shutdown`
/// arrives.
pub fn drain_round(first: EngineMsg, mut next: impl FnMut() -> Option<EngineMsg>) -> Round {
    let mut round = Round::new();
    let mut drained = 1usize;
    if !round.push(first) {
        return round;
    }
    while drained < DRAIN_CAP {
        let Some(msg) = next() else { break };
        drained += 1;
        if !round.push(msg) {
            break;
        }
    }
    round
}

/// Flatten per-request item lists into one coalesced list, returning
/// each request's slice of it for [`scatter`].
pub fn flatten<T>(parts: Vec<Vec<T>>) -> (Vec<T>, Vec<Range<usize>>) {
    let mut flat = Vec::with_capacity(parts.iter().map(Vec::len).sum());
    let mut bounds = Vec::with_capacity(parts.len());
    for p in parts {
        let start = flat.len();
        flat.extend(p);
        bounds.push(start..flat.len());
    }
    (flat, bounds)
}

/// Split coalesced per-item results back per request (inverse of
/// [`flatten`]: results must be index-aligned with the flattened input).
pub fn scatter<T: Clone>(results: &[T], bounds: &[Range<usize>]) -> Vec<Vec<T>> {
    bounds.iter().map(|r| results[r.clone()].to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::batcher::{plan_batches, plan_batches_edf};
    use crate::engine::protocol::GenKind;
    use crate::testkit::{forall, gen_vec, prop_assert};
    use crate::util::rng::Rng;
    use std::sync::mpsc::channel;

    const BUCKETS: &[usize] = &[1, 4, 8, 16, 32];
    const LENS: &[usize] = &[32, 64, 96, 128];

    fn gen_msg(n_jobs: usize) -> EngineMsg {
        let (reply, _rx) = channel();
        EngineMsg::Generate {
            jobs: (0..n_jobs)
                .map(|i| GenJob::new(vec![i as u32 + 1], GenKind::Full, 0.8))
                .collect(),
            deadline_ms: None,
            reply,
        }
    }

    fn prm_msg(n: usize) -> EngineMsg {
        let (reply, _rx) = channel();
        EngineMsg::PrmScore {
            prefixes: (0..n).map(|i| vec![i as u32]).collect(),
            reply,
        }
    }

    #[test]
    fn drain_sorts_messages_into_queues() {
        let (info_reply, _rx) = channel();
        let mut queued = vec![
            prm_msg(3),
            gen_msg(2),
            EngineMsg::Info { reply: info_reply },
            prm_msg(1),
        ]
        .into_iter();
        let round = drain_round(gen_msg(4), || queued.next());
        assert_eq!(round.generates.len(), 2);
        assert_eq!(round.prm.len(), 2);
        assert_eq!(round.others.len(), 1);
        assert_eq!(round.len(), 5);
        assert!(!round.shutdown);
        assert_eq!(round.generates[0].jobs.len(), 4); // first stays first
        assert_eq!(round.prm[0].prefixes.len(), 3);
    }

    #[test]
    fn shutdown_stops_the_drain_but_keeps_drained_work() {
        let mut queued = vec![prm_msg(2), EngineMsg::Shutdown, gen_msg(9)].into_iter();
        let round = drain_round(gen_msg(1), || queued.next());
        assert!(round.shutdown);
        assert_eq!(round.generates.len(), 1); // the post-shutdown msg is NOT drained
        assert_eq!(round.prm.len(), 1);
    }

    #[test]
    fn shutdown_first_is_an_empty_round() {
        let mut queued = vec![gen_msg(1)].into_iter();
        let round = drain_round(EngineMsg::Shutdown, || queued.next());
        assert!(round.shutdown);
        assert!(round.is_empty());
    }

    #[test]
    fn drain_caps_a_burst_and_leaves_the_rest_queued() {
        // an endless supply of messages must not extend the round past
        // DRAIN_CAP; the supply is untouched beyond the cap
        let mut pulled = 0usize;
        let round = drain_round(gen_msg(1), || {
            pulled += 1;
            Some(prm_msg(1))
        });
        assert_eq!(round.len(), DRAIN_CAP);
        assert_eq!(round.generates.len(), 1);
        assert_eq!(round.prm.len(), DRAIN_CAP - 1);
        assert_eq!(pulled, DRAIN_CAP - 1, "no message pulled past the cap");
        assert!(!round.shutdown);
    }

    #[test]
    fn flatten_scatter_roundtrip() {
        let parts = vec![vec![1, 2], vec![], vec![3, 4, 5]];
        let (flat, bounds) = flatten(parts.clone());
        assert_eq!(flat, vec![1, 2, 3, 4, 5]);
        assert_eq!(scatter(&flat, &bounds), parts);
    }

    // ---- properties ----

    #[test]
    fn prop_coalesced_elementwise_op_equals_serial() {
        // Cross-op coalescing contract for PRM scoring / embedding: an
        // elementwise op applied to the flattened batch and scattered
        // back equals applying it serially per request.
        let op = |prefix: &Vec<u32>| -> u64 { prefix.iter().map(|&t| t as u64 + 7).sum() };
        forall(
            "coalesced == serial (elementwise op)",
            150,
            |rng| {
                gen_vec(rng, 0..8, |r| {
                    gen_vec(r, 0..12, |r2| gen_vec(r2, 1..10, |r3| r3.below(40) as u32))
                })
            },
            |batches| {
                let serial: Vec<Vec<u64>> = batches
                    .iter()
                    .map(|b| b.iter().map(op).collect())
                    .collect();
                let (flat, bounds) = flatten(batches.clone());
                let coalesced_results: Vec<u64> = flat.iter().map(op).collect();
                let coalesced = scatter(&coalesced_results, &bounds);
                prop_assert(
                    coalesced == serial,
                    format!("coalesced {coalesced:?} != serial {serial:?}"),
                )
            },
        );
    }

    /// Deterministic mock device: each row's "generation" is a pure
    /// function of its prompt tokens, independent of batch shape — the
    /// shape-invariance the greedy (temperature-0) engine also has.
    fn mock_execute(jobs: &[GenJob], plans: &[crate::engine::batcher::BatchPlan]) -> Vec<Vec<u32>> {
        let mut results: Vec<Option<Vec<u32>>> = vec![None; jobs.len()];
        for plan in plans {
            for &ji in &plan.job_indices {
                let out: Vec<u32> = jobs[ji].tokens.iter().map(|&t| t.wrapping_mul(3) + 1).collect();
                results[ji] = Some(out);
            }
        }
        results.into_iter().map(|r| r.expect("plan covered every job")).collect()
    }

    #[test]
    fn prop_coalesced_generate_equals_serial() {
        // The full merge pipeline — flatten requests, bin-pack + EDF
        // order plans, execute, scatter by request bounds — returns to
        // every request exactly what planning and executing its own
        // messages serially would have.
        forall(
            "coalesced == serial (generate merge)",
            120,
            |rng| {
                gen_vec(rng, 1..6, |r| {
                    let n = r.range(1, 9) as usize;
                    let deadline = if r.below(2) == 0 {
                        f64::INFINITY
                    } else {
                        r.f64() * 300.0
                    };
                    let jobs: Vec<GenJob> = (0..n)
                        .map(|_| {
                            let len = r.range(1, 24) as usize;
                            let kind = if r.below(2) == 0 {
                                GenKind::Full
                            } else {
                                GenKind::Chunk
                            };
                            GenJob::new(
                                (0..len).map(|_| r.below(40) as u32).collect(),
                                kind,
                                if r.below(2) == 0 { 0.8 } else { 0.5 },
                            )
                        })
                        .collect();
                    (jobs, deadline)
                })
            },
            |reqs| {
                // serial: each request planned and executed on its own
                let serial: Vec<Vec<Vec<u32>>> = reqs
                    .iter()
                    .map(|(jobs, _)| {
                        let plans = plan_batches(jobs, BUCKETS, LENS, 32);
                        mock_execute(jobs, &plans)
                    })
                    .collect();
                // coalesced: one flattened job list with per-job deadlines
                let mut all_jobs = Vec::new();
                let mut deadlines = Vec::new();
                let mut bounds = Vec::new();
                for (jobs, d) in reqs {
                    let start = all_jobs.len();
                    all_jobs.extend(jobs.iter().cloned());
                    deadlines.resize(all_jobs.len(), *d);
                    bounds.push(start..all_jobs.len());
                }
                let plans = plan_batches_edf(&all_jobs, &deadlines, BUCKETS, LENS, 32);
                let merged = mock_execute(&all_jobs, &plans);
                let coalesced = scatter(&merged, &bounds);
                prop_assert(
                    coalesced == serial,
                    format!("coalesced {coalesced:?} != serial {serial:?}"),
                )
            },
        );
    }
}
