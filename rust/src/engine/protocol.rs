//! Message types between coordinator threads and the engine thread.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;

/// What kind of generation call a job needs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GenKind {
    /// Full candidate generation: stop at EOS, up to `gen_max_new` tokens.
    Full,
    /// Beam-search chunk: stop at EOS or `;`, up to `chunk_max_new`.
    Chunk,
}

impl GenKind {
    /// Stable wire/diagnostic name.
    pub fn as_str(self) -> &'static str {
        match self {
            GenKind::Full => "full",
            GenKind::Chunk => "chunk",
        }
    }

    /// Inverse of [`GenKind::as_str`], for the wire decoder.
    pub fn parse(s: &str) -> crate::error::Result<GenKind> {
        match s {
            "full" => Ok(GenKind::Full),
            "chunk" => Ok(GenKind::Chunk),
            other => Err(crate::error::Error::net(format!(
                "unknown generation kind '{other}' (expected 'full' or 'chunk')"
            ))),
        }
    }
}

/// One sequence job (a candidate to generate or a beam to extend).
///
/// Beyond the prompt, a job carries its share of the per-request budget:
/// a hard cap on new tokens and a shared cooperative cancel flag. Both
/// are enforced *inside* the engine's decode accounting loop — see
/// [`crate::engine::preempt`] — so a single batched call halts
/// mid-generation instead of merely truncating the bookkeeping afterwards.
#[derive(Debug, Clone)]
pub struct GenJob {
    /// Prompt token ids (un-padded).
    pub tokens: Vec<u32>,
    pub kind: GenKind,
    /// Sampling temperature (same value batches together).
    pub temperature: f32,
    /// Per-job cap on generated tokens; the engine stops this row's
    /// decode once reached. `None` = the executable's own limit.
    pub max_new_tokens: Option<usize>,
    /// Shared cooperative cancel flag (typically the request's
    /// `Budget::cancel`); checked between decode steps.
    pub cancel: Option<Arc<AtomicBool>>,
    /// Second cooperative stop flag, independent of the request-level
    /// cancel: strategies scope it to a *subset* of their jobs (e.g.
    /// `mv_early` shares one per wave so a decided vote retires the
    /// wave's still-decoding rows) without displacing `Budget::cancel`.
    /// Atomics cannot be OR-combined after the fact, so the job carries
    /// both and the decode loop checks either.
    pub stop: Option<Arc<AtomicBool>>,
}

impl GenJob {
    /// An unbudgeted job (no cap, no cancel flag).
    pub fn new(tokens: Vec<u32>, kind: GenKind, temperature: f32) -> GenJob {
        GenJob {
            tokens,
            kind,
            temperature,
            max_new_tokens: None,
            cancel: None,
            stop: None,
        }
    }

    pub fn with_max_new_tokens(mut self, cap: usize) -> GenJob {
        self.max_new_tokens = Some(cap);
        self
    }

    pub fn with_cancel(mut self, flag: Arc<AtomicBool>) -> GenJob {
        self.cancel = Some(flag);
        self
    }

    /// Attach the secondary (job-subset) stop flag.
    pub fn with_stop(mut self, flag: Arc<AtomicBool>) -> GenJob {
        self.stop = Some(flag);
        self
    }

    /// Either cooperative stop flag is set.
    pub fn cancelled(&self) -> bool {
        let up = |f: &Option<Arc<AtomicBool>>| {
            f.as_ref().is_some_and(|f| f.load(Ordering::Relaxed))
        };
        up(&self.cancel) || up(&self.stop)
    }
}

/// Result for one sequence job.
#[derive(Debug, Clone)]
pub struct GenResult {
    /// Generated token ids (stop token included; pad stripped).
    pub tokens: Vec<u32>,
    /// Wall/sim time of the batched call this job rode in (ms). All jobs
    /// in a call share it — that is precisely the latency semantics of a
    /// parallel batched generate.
    pub call_ms: f64,
    /// Number of jobs that shared the call (diagnostic).
    pub batch_size: usize,
    /// The engine halted this row before its natural end — deadline
    /// passed, cancel flag flipped, or the per-job token cap bit. The
    /// returned `tokens` are the partial prefix actually "generated"
    /// before the halt.
    pub preempted: bool,
}

/// Which query embedding to compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EmbedKind {
    /// Max-pooled final hidden states ("Qwen-style", appendix A.1).
    Pool,
    /// Mean-pooled token embeddings ("BERT-style", appendix A.3).
    Small,
}

impl EmbedKind {
    /// Stable wire/diagnostic name.
    pub fn as_str(self) -> &'static str {
        match self {
            EmbedKind::Pool => "pool",
            EmbedKind::Small => "small",
        }
    }

    /// Inverse of [`EmbedKind::as_str`], for the wire decoder.
    pub fn parse(s: &str) -> crate::error::Result<EmbedKind> {
        match s {
            "pool" => Ok(EmbedKind::Pool),
            "small" => Ok(EmbedKind::Small),
            other => Err(crate::error::Error::net(format!(
                "unknown embed kind '{other}' (expected 'pool' or 'small')"
            ))),
        }
    }
}

/// Probe training outcome.
#[derive(Debug, Clone)]
pub struct ProbeTrainReport {
    pub steps: usize,
    pub final_train_loss: f64,
    pub best_val_loss: f64,
    /// (epoch, train_loss, val_loss) per epoch.
    pub curve: Vec<(usize, f64, f64)>,
    /// Trained parameters, flat f32 in manifest order.
    pub params: Vec<f32>,
}

/// Requests the engine thread serves.
///
/// Messages queued concurrently are drained into scheduling rounds by
/// [`crate::engine::scheduler`]: `Generate`, `PrmScore` and `Embed`
/// requests coalesce into shared bucket-shaped device calls; probe and
/// info messages execute in arrival order.
pub enum EngineMsg {
    /// Generate a batch of sequence jobs; one reply per job, in order.
    /// `deadline_ms` is an *absolute* engine-clock timestamp; once it
    /// passes, remaining decode work for these jobs is preempted.
    Generate {
        jobs: Vec<GenJob>,
        deadline_ms: Option<f64>,
        reply: Sender<crate::error::Result<Vec<GenResult>>>,
    },
    /// Score CoT prefixes with the PRM. Input: (tokens, true_len) pairs.
    PrmScore {
        prefixes: Vec<Vec<u32>>,
        reply: Sender<crate::error::Result<Vec<f32>>>,
    },
    /// Embed queries. Input: token id lists (≤ query_len).
    Embed {
        kind: EmbedKind,
        queries: Vec<Vec<u32>>,
        reply: Sender<crate::error::Result<Vec<Vec<f32>>>>,
    },
    /// Probe forward on feature rows (uses the engine's current probe
    /// parameters — initial or trained).
    ProbeFwd {
        feats: Vec<Vec<f32>>,
        reply: Sender<crate::error::Result<Vec<f32>>>,
    },
    /// Train the probe on (features, soft-label) pairs with early
    /// stopping on a validation split; engine keeps the trained params.
    ProbeTrain {
        train_feats: Vec<Vec<f32>>,
        train_labels: Vec<f32>,
        val_feats: Vec<Vec<f32>>,
        val_labels: Vec<f32>,
        epochs: usize,
        patience: usize,
        reply: Sender<crate::error::Result<ProbeTrainReport>>,
    },
    /// Replace the engine's probe parameters (e.g. loaded from disk).
    ProbeLoad {
        params: Vec<f32>,
        reply: Sender<crate::error::Result<()>>,
    },
    /// Diagnostics: compile-time totals, metrics snapshot.
    Info {
        reply: Sender<crate::error::Result<crate::util::json::Value>>,
    },
    /// Shut the engine thread down cleanly.
    Shutdown,
}

impl EngineMsg {
    /// Short op name for logs and scheduler diagnostics.
    pub fn op_name(&self) -> &'static str {
        match self {
            EngineMsg::Generate { .. } => "generate",
            EngineMsg::PrmScore { .. } => "prm_score",
            EngineMsg::Embed { .. } => "embed",
            EngineMsg::ProbeFwd { .. } => "probe_fwd",
            EngineMsg::ProbeTrain { .. } => "probe_train",
            EngineMsg::ProbeLoad { .. } => "probe_load",
            EngineMsg::Info { .. } => "info",
            EngineMsg::Shutdown => "shutdown",
        }
    }
}
