//! The engine thread: serves [`EngineMsg`]s against a pluggable
//! [`Backend`], plus the PJRT [`DeviceBackend`] implementation.
//!
//! The thread owns everything backend-*independent*: the coalescing
//! serve loop ([`crate::engine::scheduler`]), bin-packed EDF planning,
//! shape validation, the decode-accounting/preemption loop, clock cost
//! charges and metrics. What actually executes a bucket-shaped call is
//! behind the [`Backend`] trait (`engine/backend.rs`): the
//! [`DeviceBackend`] below drives the AOT'd executables through PJRT
//! (weights uploaded once, per-call activations staged through reusable
//! host arenas so the hot path performs no per-call host allocation),
//! while [`crate::engine::backend::SimBackend`] emulates the trained
//! models deterministically with no artifacts at all. Because charges
//! and accounting live here, every backend gets identical budget,
//! preemption and latency semantics for free.
//!
//! The serve loop works in scheduling rounds
//! ([`crate::engine::scheduler`]): all queued `Generate`, `PrmScore` and
//! `Embed` messages coalesce into shared bucket-shaped calls, and
//! planned generate calls dispatch earliest-deadline-first.
//!
//! On backends that step natively ([`Backend::stepping`]), generates run
//! through the **continuous-batching** path instead of round-at-a-time:
//! each planned session keeps a persistent slot table, rows retire the
//! moment their budget runs out (freeing real decode steps, not just
//! trimming the accounting), newly-arrived `Generate` jobs are admitted
//! into freed slots mid-decode, and each request's reply fires as soon
//! as its own jobs finish — mid-session, not at the round boundary. At
//! temperature 0 the continuous path is byte-identical to the round
//! path, and under the sim clock it charges the identical cost sequence
//! when no mid-decode arrivals occur.

use crate::engine::backend::{Backend, DecodeSession, EngineShapes};
use crate::engine::batcher::{pack_bins, pick_slot_admission, plan_batches_edf, BatchPlan};
use crate::engine::cache::{EngineCache, ScoreKey, ScoreValue};
use crate::engine::preempt::{cut_replayed_row, run_decode_accounting, RowBudget};
use crate::engine::protocol::*;
use crate::engine::scheduler::{self, drain_round, EmbedReq, GenerateReq, PrmReq, Round};
use crate::error::{Error, Result};
use crate::metrics::EngineMetrics;
use crate::runtime::{ExecutableSet, WeightSet};
use crate::util::clock::{CostEvent, SharedClock};
use crate::util::json::Value;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::{log_debug, log_info};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::Receiver;
use std::sync::Arc;

/// Scatter one coalesced op's per-item results back per request (the
/// single copy of the round reply contract), or broadcast the one
/// failure to every coalesced requester.
fn send_scattered<T: Clone>(
    outcome: Result<Vec<T>>,
    replies: Vec<std::sync::mpsc::Sender<Result<Vec<T>>>>,
    bounds: &[std::ops::Range<usize>],
) {
    match outcome {
        Ok(results) => {
            let parts = scheduler::scatter(&results, bounds);
            for (reply, part) in replies.into_iter().zip(parts) {
                let _ = reply.send(Ok(part));
            }
        }
        Err(e) => {
            // replicate() preserves the error variant — in particular a
            // transient Error::Net from a remote shard stays transient,
            // so every coalesced requester's pool failover can engage
            for reply in replies {
                let _ = reply.send(Err(e.replicate()));
            }
        }
    }
}

/// The backend-independent engine loop: scheduling, planning, budget
/// accounting, metrics. One per engine thread.
pub struct EngineThread {
    backend: Box<dyn Backend>,
    pub shapes: EngineShapes,
    clock: SharedClock,
    metrics: Arc<EngineMetrics>,
    /// Cross-request cache tier ([`crate::engine::cache`]). `None`
    /// (the default-off config) keeps every code path byte-identical
    /// to the uncached build — see `docs/caching.md`.
    cache: Option<Arc<EngineCache>>,
    /// Serve generates iteration-by-iteration when the backend steps
    /// natively ([`EngineConfig::continuous`]
    /// (crate::config::EngineConfig)). `false` forces the round path —
    /// the equivalence baseline.
    continuous: bool,
}

impl EngineThread {
    pub fn new(
        backend: Box<dyn Backend>,
        clock: SharedClock,
        metrics: Arc<EngineMetrics>,
    ) -> EngineThread {
        let shapes = backend.shapes().clone();
        EngineThread {
            backend,
            shapes,
            clock,
            metrics,
            cache: None,
            continuous: true,
        }
    }

    /// Attach the shared cross-request cache tier. Every engine of a
    /// pool shares one [`EngineCache`], so a stem decoded on engine 0
    /// is a hit on engine 3.
    pub fn with_cache(mut self, cache: Option<Arc<EngineCache>>) -> EngineThread {
        self.cache = cache;
        self
    }

    /// Enable/disable the continuous generate path (it only takes
    /// effect on backends whose [`Backend::stepping`] is `true`).
    pub fn with_continuous(mut self, continuous: bool) -> EngineThread {
        self.continuous = continuous;
        self
    }

    /// Generates run iteration-level iff the config asked for it *and*
    /// the backend steps natively. Buffered adapters (remote links,
    /// legacy backends) stay on the round path, where run-to-completion
    /// semantics — including the real-clock proration fallback — are
    /// exactly right because the compute is already spent when the call
    /// returns.
    fn continuous_active(&self) -> bool {
        self.continuous && self.backend.stepping()
    }

    /// Blocking serve loop. Consumes messages until `Shutdown` or channel
    /// close, one scheduling round at a time: every queued message is
    /// drained into per-op queues and each op executes as one coalesced
    /// pass ([`crate::engine::scheduler`] has the ordering contract).
    pub fn serve(mut self, rx: Receiver<EngineMsg>) {
        loop {
            let first = match rx.recv() {
                Ok(m) => m,
                Err(_) => return,
            };
            let round = drain_round(first, || rx.try_recv().ok());
            let shutdown = self.run_round(round, &mut || rx.try_recv().ok());
            if shutdown {
                return;
            }
        }
    }

    /// Execute one scheduling round: control-plane ops in arrival order,
    /// then coalesced PRM scoring, coalesced embeds, and finally the
    /// merged generate round (EDF-ordered plans). Scoring and embeds run
    /// before generation because they are short and unblock workers to
    /// contribute generate jobs to the next round. `poll` lets the
    /// continuous generate path keep admitting arrivals mid-decode;
    /// returns whether a `Shutdown` was seen (in the round or while
    /// polling).
    fn run_round(&mut self, round: Round, poll: &mut dyn FnMut() -> Option<EngineMsg>) -> bool {
        let n_msgs = round.len();
        if n_msgs > 1 {
            self.metrics.coalesced_msgs.add((n_msgs - 1) as u64);
        }
        if n_msgs > 0 {
            self.metrics.sched_rounds.inc();
        }
        let Round {
            generates,
            prm,
            embeds,
            others,
            shutdown,
        } = round;
        for msg in others {
            self.dispatch(msg);
        }
        if !prm.is_empty() {
            self.prm_round(prm);
        }
        if !embeds.is_empty() {
            self.embed_round(embeds);
        }
        if !generates.is_empty() {
            if self.continuous_active() {
                return self.generate_continuous(generates, poll, shutdown);
            }
            self.generate_merged(generates);
        }
        shutdown
    }

    /// Serve one control-plane message (the non-coalesced ops).
    fn dispatch(&mut self, msg: EngineMsg) {
        log_debug!("engine: control-plane {}", msg.op_name());
        match msg {
            EngineMsg::Generate {
                jobs,
                deadline_ms,
                reply,
            } => self.generate_merged(vec![GenerateReq {
                jobs,
                deadline_ms,
                reply,
            }]),
            EngineMsg::PrmScore { prefixes, reply } => {
                self.prm_round(vec![PrmReq { prefixes, reply }])
            }
            EngineMsg::Embed {
                kind,
                queries,
                reply,
            } => self.embed_round(vec![EmbedReq {
                kind,
                queries,
                reply,
            }]),
            EngineMsg::ProbeFwd { feats, reply } => {
                let _ = reply.send(self.backend.probe_fwd(&feats));
            }
            EngineMsg::ProbeTrain {
                train_feats,
                train_labels,
                val_feats,
                val_labels,
                epochs,
                patience,
                reply,
            } => {
                let out = self.backend.probe_train(
                    &train_feats,
                    &train_labels,
                    &val_feats,
                    &val_labels,
                    epochs,
                    patience,
                );
                if out.is_ok() {
                    self.invalidate_cache();
                }
                let _ = reply.send(out);
            }
            EngineMsg::ProbeLoad { params, reply } => {
                let out = self.backend.probe_load(params);
                if out.is_ok() {
                    self.invalidate_cache();
                }
                let _ = reply.send(out);
            }
            EngineMsg::Info { reply } => {
                let _ = reply.send(Ok(self.info()));
            }
            EngineMsg::Shutdown => {}
        }
    }

    /// A successful probe swap changes what cached scores mean — drop
    /// every entry (generation-stamped, so racing inserts stamped with
    /// the old layout are dropped too).
    fn invalidate_cache(&self) {
        if let Some(c) = &self.cache {
            c.invalidate();
        }
    }

    // ------------------------------------------------------------------
    // generation
    // ------------------------------------------------------------------

    fn generate_merged(&mut self, requests: Vec<GenerateReq>) {
        if requests.len() > 1 {
            self.metrics
                .coalesced_generates
                .add((requests.len() - 1) as u64);
        }
        // flatten with request boundaries; each request's batch-level
        // deadline becomes a per-job absolute deadline so merged calls
        // preempt each request independently (continuous-batching
        // eviction, not whole-call abort)
        let mut all_jobs = Vec::new();
        let mut deadlines = Vec::new();
        let mut bounds = Vec::new();
        let mut replies = Vec::new();
        for req in requests {
            let start = all_jobs.len();
            all_jobs.extend(req.jobs);
            let d = req.deadline_ms.unwrap_or(f64::INFINITY);
            deadlines.resize(all_jobs.len(), d);
            bounds.push(start..all_jobs.len());
            replies.push(req.reply);
        }

        let outcome = self.generate_all(&all_jobs, &deadlines);
        send_scattered(outcome, replies, &bounds);
    }

    fn generate_all(&mut self, jobs: &[GenJob], deadlines: &[f64]) -> Result<Vec<GenResult>> {
        debug_assert_eq!(jobs.len(), deadlines.len());
        let Some(cache) = self.cache.clone() else {
            return self.generate_executed(jobs, deadlines, None);
        };

        // Classify every temp-0 job before planning. Reuse is *exact
        // prompt* only — the Backend contract guarantees a temp-0 row
        // depends on nothing but its prompt, so an exact (kind, prompt)
        // hit replays byte-identically; extending a cached stem with
        // fresh decoding would not (docs/caching.md has the argument).
        // Identical live temp-0 jobs in one round dedup onto a single
        // "leader" row; followers replay its natural row.
        enum Role {
            Live,
            Follower(usize),
            Replay(Vec<u32>),
        }
        let stamp = cache.generation();
        let now = self.clock.now_ms();
        let mut leader_of: HashMap<(GenKind, &[u32]), usize> = HashMap::new();
        let mut roles: Vec<Role> = Vec::with_capacity(jobs.len());
        let mut n_cached = 0usize;
        for (ji, job) in jobs.iter().enumerate() {
            // Dead rows (spent deadline / preset cancel) stay on the
            // executed path so they take the same all-dead fast path
            // as the uncached build — and a dead leader never absorbs
            // a live follower.
            let dead = now >= deadlines[ji] || job.cancelled();
            let role = if job.temperature != 0.0 || dead {
                Role::Live
            } else if let Some(&leader) = leader_of.get(&(job.kind, job.tokens.as_slice())) {
                // counted before the cache lookup: 8 identical jobs in
                // one round are 1 miss + 7 hits, not 8 misses
                cache.metrics.hits.inc();
                Role::Follower(leader)
            } else if let Some(natural) = cache.lookup_gen(job.kind, &job.tokens) {
                Role::Replay(natural)
            } else {
                leader_of.insert((job.kind, job.tokens.as_slice()), ji);
                Role::Live
            };
            if !matches!(role, Role::Live) {
                n_cached += 1;
            }
            roles.push(role);
        }

        if n_cached == 0 {
            // nothing to replay: execute as usual, keeping natural rows
            // so this round's temp-0 leaders seed the cache
            let mut naturals: Vec<Option<Vec<u32>>> = vec![None; jobs.len()];
            let results = self.generate_executed(jobs, deadlines, Some(&mut naturals))?;
            for (ji, job) in jobs.iter().enumerate() {
                if job.temperature == 0.0 {
                    if let Some(nat) = naturals[ji].take() {
                        cache.insert_gen(job.kind, &job.tokens, &nat, stamp);
                    }
                }
            }
            return Ok(results);
        }

        // Execute only the live subset — cached rows are subtracted
        // from the batch plan entirely (smaller buckets, fewer charged
        // decode steps), which is the whole speed win.
        let mut live_jobs: Vec<GenJob> = Vec::with_capacity(jobs.len() - n_cached);
        let mut live_deadlines: Vec<f64> = Vec::with_capacity(jobs.len() - n_cached);
        let mut live_pos: Vec<Option<usize>> = vec![None; jobs.len()];
        for (ji, role) in roles.iter().enumerate() {
            if matches!(role, Role::Live) {
                live_pos[ji] = Some(live_jobs.len());
                live_jobs.push(jobs[ji].clone());
                live_deadlines.push(deadlines[ji]);
            }
        }
        let mut naturals: Vec<Option<Vec<u32>>> = vec![None; live_jobs.len()];
        let live_results =
            self.generate_executed(&live_jobs, &live_deadlines, Some(&mut naturals))?;

        let mut results: Vec<Option<GenResult>> = vec![None; jobs.len()];
        for (ji, job) in jobs.iter().enumerate() {
            if let Some(p) = live_pos[ji] {
                if job.temperature == 0.0 {
                    if let Some(nat) = naturals[p].as_deref() {
                        cache.insert_gen(job.kind, &job.tokens, nat, stamp);
                    }
                }
                results[ji] = Some(live_results[p].clone());
            }
        }
        for (ji, role) in roles.iter().enumerate() {
            let natural = match role {
                Role::Live => continue,
                Role::Replay(nat) => Some(nat.clone()),
                Role::Follower(leader) => live_pos[*leader].and_then(|p| naturals[p].clone()),
            };
            results[ji] = Some(self.replay_row(&cache, &jobs[ji], deadlines[ji], natural));
        }
        Ok(results
            .into_iter()
            .map(|r| r.expect("every job is live, replayed or deduped"))
            .collect())
    }

    /// Serve one cached or deduplicated row: the same cap/deadline/
    /// cancel cut an executed row gets, but zero decode steps charged —
    /// the clock does not move ([`cut_replayed_row`]). A follower whose
    /// leader emitted nothing (its plan was already dead by dispatch
    /// time) gets the same empty preempted result the leader got.
    fn replay_row(
        &self,
        cache: &EngineCache,
        job: &GenJob,
        deadline_ms: f64,
        natural: Option<Vec<u32>>,
    ) -> GenResult {
        let Some(natural) = natural else {
            self.metrics.preempted_rows.inc();
            return GenResult {
                tokens: Vec::new(),
                call_ms: 0.0,
                batch_size: 1,
                preempted: true,
            };
        };
        let budget = RowBudget {
            natural_len: natural.len(),
            cap: job.max_new_tokens.unwrap_or(usize::MAX),
            deadline_ms,
            cancel: job.cancel.clone(),
            stop: job.stop.clone(),
        };
        let cut = cut_replayed_row(&budget, self.clock.now_ms());
        cache.metrics.decode_steps_saved.add(cut.emitted as u64);
        self.metrics.tokens_generated.add(cut.emitted as u64);
        if cut.preempted {
            self.metrics.preempted_rows.inc();
        }
        GenResult {
            tokens: natural[..cut.emitted].to_vec(),
            call_ms: 0.0,
            batch_size: 1,
            preempted: cut.preempted,
        }
    }

    /// The uncached execution path: bin-packed EDF plans against the
    /// backend, with full decode accounting. When `naturals` is given
    /// (cache enabled), each executed row's full pre-cut output is
    /// stored there so the caller can seed the cache — entries stay
    /// `None` for rows whose plan was skipped as all-dead.
    fn generate_executed(
        &mut self,
        jobs: &[GenJob],
        deadlines: &[f64],
        mut naturals: Option<&mut Vec<Option<Vec<u32>>>>,
    ) -> Result<Vec<GenResult>> {
        debug_assert_eq!(jobs.len(), deadlines.len());
        // bin-packed plans, dispatched earliest-deadline-first
        let plans = plan_batches_edf(
            jobs,
            deadlines,
            &self.shapes.batch_buckets,
            &self.shapes.chunk_lens,
            self.shapes.query_len,
        );
        let mut results: Vec<Option<GenResult>> = vec![None; jobs.len()];
        for plan in &plans {
            // A plan whose every row is already dead (deadline passed or
            // cancelled before the call starts) is not executed at all:
            // the engine refuses to start work for expired requests.
            let now = self.clock.now_ms();
            let all_dead = plan
                .job_indices
                .iter()
                .all(|&ji| now >= deadlines[ji] || jobs[ji].cancelled());
            if all_dead {
                for &ji in &plan.job_indices {
                    results[ji] = Some(GenResult {
                        tokens: Vec::new(),
                        call_ms: 0.0,
                        batch_size: plan.job_indices.len(),
                        preempted: true,
                    });
                }
                self.metrics
                    .preempted_rows
                    .add(plan.job_indices.len() as u64);
                continue;
            }

            // shape validation is backend-independent: every backend
            // rejects prompts that overflow the planned length bucket
            let b = plan.bucket;
            let l = plan.len_bucket;
            let mut prompts: Vec<&[u32]> = Vec::with_capacity(plan.job_indices.len());
            for &ji in &plan.job_indices {
                let t = &jobs[ji].tokens;
                if t.len() > l {
                    return Err(Error::Engine(format!(
                        "prompt of {} tokens exceeds length bucket {l}",
                        t.len()
                    )));
                }
                prompts.push(t);
            }

            // the most urgent deadline among this plan's rows, as a hint
            // for backends that can act on it (RemoteBackend ships it to
            // the server so *its* preemption loop sees the budget too;
            // local backends ignore it — preemption happens below)
            let plan_deadline = plan
                .job_indices
                .iter()
                .map(|&ji| deadlines[ji])
                .fold(f64::INFINITY, f64::min);
            self.backend.deadline_hint(plan_deadline);

            let t0 = self.clock.now_ms();
            let mut rows = self.backend.generate(plan, &prompts)?;
            if rows.len() < plan.job_indices.len() {
                return Err(Error::Engine(format!(
                    "backend generated {} of {} rows",
                    rows.len(),
                    plan.job_indices.len()
                )));
            }

            // sim-clock cost: prefill, then the preemptible decode
            // accounting loop — one charged step per emitted column,
            // halting rows whose deadline/cancel/cap budget runs out
            self.clock.charge(CostEvent::Prefill { batch: b, len: l });
            let after_call = self.clock.now_ms();
            let is_sim = self.clock.is_sim();
            let budgets: Vec<RowBudget> = plan
                .job_indices
                .iter()
                .enumerate()
                .map(|(row, &ji)| {
                    let natural_len = rows[row].len();
                    let mut cap = jobs[ji].max_new_tokens.unwrap_or(usize::MAX);
                    let mut deadline_ms = deadlines[ji];
                    if !is_sim && after_call >= deadline_ms {
                        // Real clock on the *round* path: the call
                        // already happened by the time we account for
                        // it, so exact per-step preemption is
                        // impossible — prorate the row's output to the
                        // fraction of the call that fit before its
                        // deadline (partial results, not a zeroed
                        // request). Steppable backends never get here:
                        // the continuous path checks the real clock
                        // between decode steps, making preemption
                        // step-granular with no proration needed.
                        let frac = ((deadline_ms - t0) / (after_call - t0).max(1e-9))
                            .clamp(0.0, 1.0);
                        cap = cap.min((natural_len as f64 * frac).floor() as usize);
                        deadline_ms = f64::INFINITY;
                    }
                    RowBudget {
                        natural_len,
                        cap,
                        deadline_ms,
                        cancel: jobs[ji].cancel.clone(),
                        stop: jobs[ji].stop.clone(),
                    }
                })
                .collect();
            let (cuts, steps) =
                run_decode_accounting(self.clock.as_ref(), b, &budgets, plan.max_steps);
            let call_ms = self.clock.now_ms() - t0;

            // metrics
            self.metrics.prefill_calls.inc();
            self.metrics.decode_calls.inc();
            let real_rows: usize = cuts.iter().map(|c| c.emitted).sum();
            let n_preempted = cuts.iter().filter(|c| c.preempted).count();
            self.metrics.decode_rows.add(real_rows as u64);
            self.metrics
                .padded_rows
                .add((b * steps).saturating_sub(real_rows) as u64);
            self.metrics.tokens_generated.add(real_rows as u64);
            self.metrics.preempted_rows.add(n_preempted as u64);
            self.metrics.decode_latency.record(call_ms);
            log_debug!(
                "{} {:?} b{b}: {} jobs, {} steps, {} preempted, {:.1}ms",
                self.backend.name(),
                plan.kind,
                plan.job_indices.len(),
                steps,
                n_preempted,
                call_ms
            );

            for (row, &ji) in plan.job_indices.iter().enumerate() {
                let n = cuts[row].emitted;
                results[ji] = Some(GenResult {
                    tokens: rows[row][..n].to_vec(),
                    call_ms,
                    batch_size: plan.job_indices.len(),
                    preempted: cuts[row].preempted,
                });
                if let Some(nat) = naturals.as_deref_mut() {
                    // the full pre-cut row: what the cache stores, so a
                    // later hit can be re-cut against *its* budget
                    nat[ji] = Some(std::mem::take(&mut rows[row]));
                }
            }
        }
        Ok(results
            .into_iter()
            .map(|r| r.expect("batcher covered every job"))
            .collect())
    }

    // ------------------------------------------------------------------
    // continuous generation (iteration-level scheduling)
    // ------------------------------------------------------------------

    /// The continuous generate path: plan the queued jobs into
    /// EDF-ordered sessions, run each session one decode step at a
    /// time, and between steps retire rows whose budget ran out, admit
    /// newly-arrived jobs into freed slots, and answer each request the
    /// moment its own jobs finish. Returns whether a `Shutdown` was
    /// seen; work already accepted still completes first.
    fn generate_continuous(
        &mut self,
        requests: Vec<GenerateReq>,
        poll: &mut dyn FnMut() -> Option<EngineMsg>,
        no_new: bool,
    ) -> bool {
        if requests.len() > 1 {
            self.metrics
                .coalesced_generates
                .add((requests.len() - 1) as u64);
        }
        let mut st = Continuous {
            requests: Vec::new(),
            queue: ContQueue::default(),
            followers: HashMap::new(),
            shutdown: no_new,
        };
        for req in requests {
            self.cont_intake(&mut st, req);
        }
        if let Err(e) = self.cont_drive(&mut st, poll) {
            // a backend error fails every request still in flight;
            // requests that fully resolved mid-session already replied
            for r in &st.requests {
                if r.remaining > 0 {
                    let _ = r.reply.send(Err(e.replicate()));
                }
            }
        }
        st.shutdown
    }

    /// Accept one request into the continuous run: zero-job requests
    /// answer immediately; with the cache enabled, temp-0 jobs go
    /// through the same replay / leader-dedup fronting as the round
    /// path (dead rows skip it, so a dead leader never absorbs a live
    /// follower); everything else queues for a slot.
    fn cont_intake(&mut self, st: &mut Continuous, req: GenerateReq) {
        let deadline = req.deadline_ms.unwrap_or(f64::INFINITY);
        let rid = st.requests.len();
        let n = req.jobs.len();
        st.requests.push(ContRequest {
            reply: req.reply,
            results: vec![None; n],
            remaining: n,
        });
        if n == 0 {
            let _ = st.requests[rid].reply.send(Ok(Vec::new()));
            return;
        }
        let cache = self.cache.clone();
        let now = self.clock.now_ms();
        for (pos, job) in req.jobs.into_iter().enumerate() {
            let route = (rid, pos);
            let Some(cache) = cache.as_deref() else {
                st.queue.push(job, deadline, route, false);
                continue;
            };
            let dead = now >= deadline || job.cancelled();
            if job.temperature != 0.0 || dead {
                st.queue.push(job, deadline, route, false);
                continue;
            }
            let key = (job.kind, job.tokens.clone());
            if let Some(parked) = st.followers.get_mut(&key) {
                // a live leader for this exact prompt is queued or
                // decoding: count the dedup hit now (like the round
                // path) and resolve when its natural row lands
                cache.metrics.hits.inc();
                parked.push((job, deadline, route));
            } else if let Some(natural) = cache.lookup_gen(job.kind, &job.tokens) {
                let result = self.replay_row(cache, &job, deadline, Some(natural));
                st.resolve(route, result);
            } else {
                st.followers.insert(key, Vec::new());
                st.queue.push(job, deadline, route, true);
            }
        }
    }

    /// Run planned sessions until the queue drains (arrivals during a
    /// session refill it, so the loop replans as long as work exists).
    fn cont_drive(
        &mut self,
        st: &mut Continuous,
        poll: &mut dyn FnMut() -> Option<EngineMsg>,
    ) -> Result<()> {
        while !st.queue.is_empty() {
            let q = std::mem::take(&mut st.queue);
            let plans = plan_batches_edf(
                &q.jobs,
                &q.deadlines,
                &self.shapes.batch_buckets,
                &self.shapes.chunk_lens,
                self.shapes.query_len,
            );
            for plan in &plans {
                self.run_session(st, plan, &q, poll)?;
            }
        }
        Ok(())
    }

    /// Run one planned session to exhaustion: prefill, then the charged
    /// step loop with per-step retirement and admission. Charge order
    /// mirrors [`run_decode_accounting`] exactly — halt pass, any-live
    /// check, `DecodeStep` charge, emit — so at temp 0 with no arrivals
    /// the sim clock advances identically to the round path.
    fn run_session(
        &mut self,
        st: &mut Continuous,
        plan: &BatchPlan,
        q: &ContQueue,
        poll: &mut dyn FnMut() -> Option<EngineMsg>,
    ) -> Result<()> {
        let b = plan.bucket;
        let l = plan.len_bucket;

        // the all-dead fast path, identical to the round engine: refuse
        // to start work for requests that are already expired
        let now = self.clock.now_ms();
        let all_dead = plan
            .job_indices
            .iter()
            .all(|&ji| now >= q.deadlines[ji] || q.jobs[ji].cancelled());
        if all_dead {
            for &ji in &plan.job_indices {
                self.metrics.preempted_rows.inc();
                if q.leader[ji] {
                    self.cont_promote(st, (q.jobs[ji].kind, q.jobs[ji].tokens.clone()));
                }
                st.resolve(
                    q.routes[ji],
                    GenResult {
                        tokens: Vec::new(),
                        call_ms: 0.0,
                        batch_size: plan.job_indices.len(),
                        preempted: true,
                    },
                );
            }
            return Ok(());
        }

        // shape validation is backend-independent, as on the round path
        let mut prompts: Vec<&[u32]> = Vec::with_capacity(plan.job_indices.len());
        for &ji in &plan.job_indices {
            let t = &q.jobs[ji].tokens;
            if t.len() > l {
                return Err(Error::Engine(format!(
                    "prompt of {} tokens exceeds length bucket {l}",
                    t.len()
                )));
            }
            prompts.push(t);
        }
        let plan_deadline = plan
            .job_indices
            .iter()
            .map(|&ji| q.deadlines[ji])
            .fold(f64::INFINITY, f64::min);
        self.backend.deadline_hint(plan_deadline);

        let t0 = self.clock.now_ms();
        let mut session = self.backend.prefill(plan, &prompts)?;
        self.clock.charge(CostEvent::Prefill { batch: b, len: l });
        self.metrics.prefill_calls.inc();
        self.metrics.decode_calls.inc();

        // the persistent slot table
        let mut slots: Vec<Option<SlotRow>> = (0..b).map(|_| None).collect();
        for (slot, &ji) in plan.job_indices.iter().enumerate() {
            slots[slot] = Some(SlotRow {
                cap: q.jobs[ji].max_new_tokens.unwrap_or(usize::MAX),
                job: q.jobs[ji].clone(),
                deadline_ms: q.deadlines[ji],
                route: q.routes[ji],
                leader: q.leader[ji],
                tokens: Vec::new(),
            });
        }
        // rows with no natural output finish before any step is
        // charged — like a zero-length row never keeping a round call
        // alive
        let n_rows = plan.job_indices.len();
        for slot in std::mem::take(&mut session.empty_rows) {
            if let Some(row) = slots[slot].take() {
                self.backend.retire_row(&mut session, slot);
                self.cont_finish_row(st, row, false, n_rows, 0.0);
            }
        }

        let mut steps = 0usize;
        let mut emitted_total = 0usize;
        loop {
            // arrivals first: new jobs may join this session's free
            // slots instead of waiting for the next planning round
            if !st.shutdown {
                self.cont_poll(st, poll);
            }
            self.cont_admit(st, &mut session, &mut slots, t0)?;

            // halt pass: retire rows whose budget ran out as of now
            let now = self.clock.now_ms();
            let occupied = slots.iter().filter(|s| s.is_some()).count();
            for slot in 0..b {
                let Some(row) = &slots[slot] else { continue };
                let halted = now >= row.deadline_ms
                    || row.job.cancelled()
                    || row.tokens.len() >= row.cap;
                if halted {
                    let row = slots[slot].take().expect("slot occupied");
                    let saved = self.backend.retire_row(&mut session, slot);
                    self.metrics.retired_rows.inc();
                    self.metrics.decode_steps_saved_live.add(saved as u64);
                    self.cont_finish_row(st, row, true, occupied, now - t0);
                }
            }

            let live = slots.iter().filter(|s| s.is_some()).count();
            if live == 0 {
                break;
            }

            // one iteration: charge at the machine batch shape, step
            // the backend, hand out tokens, finish natural completions
            self.clock.charge(CostEvent::DecodeStep { batch: b });
            steps += 1;
            self.metrics.slot_steps_total.add(b as u64);
            self.metrics.slot_steps_occupied.add(live as u64);
            let rows = self.backend.decode_step(&mut session)?;
            let now = self.clock.now_ms();
            for slot in 0..b {
                let Some(tok) = rows.get(slot).copied().flatten() else {
                    continue;
                };
                let Some(row) = slots[slot].as_mut() else { continue };
                row.tokens.push(tok.token);
                emitted_total += 1;
                if tok.last {
                    let row = slots[slot].take().expect("row just stepped");
                    self.backend.retire_row(&mut session, slot);
                    self.metrics.retired_rows.inc();
                    self.cont_finish_row(st, row, false, live, now - t0);
                }
            }
        }

        let call_ms = self.clock.now_ms() - t0;
        self.metrics.decode_rows.add(emitted_total as u64);
        self.metrics
            .padded_rows
            .add((b * steps).saturating_sub(emitted_total) as u64);
        self.metrics.tokens_generated.add(emitted_total as u64);
        self.metrics.decode_latency.record(call_ms);
        log_debug!(
            "{} {:?} b{b} continuous: {} initial rows, {} steps, {:.1}ms",
            self.backend.name(),
            plan.kind,
            n_rows,
            steps,
            call_ms
        );
        Ok(())
    }

    /// Drain arrivals between decode steps (bounded like
    /// [`scheduler::drain_round`] so a burst cannot stall the step
    /// loop). Generates join the continuous run; PRM / embed / control
    /// messages execute immediately as their own mini-rounds — they
    /// keep round coalescing and never enter the slot table. A polled
    /// `Shutdown` stops further intake; accepted work still finishes.
    fn cont_poll(&mut self, st: &mut Continuous, poll: &mut dyn FnMut() -> Option<EngineMsg>) {
        let mut drained = 0usize;
        while !st.shutdown && drained < scheduler::DRAIN_CAP {
            let Some(msg) = poll() else { break };
            drained += 1;
            match msg {
                EngineMsg::Generate {
                    jobs,
                    deadline_ms,
                    reply,
                } => {
                    self.metrics.coalesced_generates.inc();
                    self.cont_intake(
                        st,
                        GenerateReq {
                            jobs,
                            deadline_ms,
                            reply,
                        },
                    );
                }
                EngineMsg::PrmScore { prefixes, reply } => {
                    self.prm_round(vec![PrmReq { prefixes, reply }])
                }
                EngineMsg::Embed {
                    kind,
                    queries,
                    reply,
                } => self.embed_round(vec![EmbedReq {
                    kind,
                    queries,
                    reply,
                }]),
                EngineMsg::Shutdown => st.shutdown = true,
                other => self.dispatch(other),
            }
        }
    }

    /// Fill the session's free slots with compatible queued jobs, in
    /// EDF order ([`pick_slot_admission`]). Each admitted row pays a
    /// batch-1 prefill and joins the live session immediately; a job
    /// already dead when its turn comes is answered empty instead of
    /// admitted, like the all-dead fast path.
    fn cont_admit(
        &mut self,
        st: &mut Continuous,
        session: &mut DecodeSession,
        slots: &mut [Option<SlotRow>],
        t0: f64,
    ) -> Result<()> {
        while !st.queue.is_empty() {
            let Some(free) = slots.iter().position(|s| s.is_none()) else {
                break;
            };
            let queued: Vec<usize> = (0..st.queue.len()).collect();
            let Some(qpos) = pick_slot_admission(
                &st.queue.jobs,
                &queued,
                &st.queue.deadlines,
                session.kind,
                session.len_bucket,
                session.temperature,
                &self.shapes.chunk_lens,
                self.shapes.query_len,
            ) else {
                break;
            };
            let (job, deadline_ms, route, leader) = st.queue.remove(qpos);
            let now = self.clock.now_ms();
            let row = SlotRow {
                cap: job.max_new_tokens.unwrap_or(usize::MAX),
                job,
                deadline_ms,
                route,
                leader,
                tokens: Vec::new(),
            };
            if now >= row.deadline_ms || row.job.cancelled() {
                self.metrics.preempted_rows.inc();
                if row.leader {
                    self.cont_promote(st, (row.job.kind, row.job.tokens.clone()));
                }
                st.resolve(
                    row.route,
                    GenResult {
                        tokens: Vec::new(),
                        call_ms: 0.0,
                        batch_size: 1,
                        preempted: true,
                    },
                );
                continue;
            }
            let has_work = self.backend.admit_row(session, free, &row.job.tokens)?;
            self.clock.charge(CostEvent::Prefill {
                batch: 1,
                len: session.len_bucket,
            });
            self.metrics.prefill_calls.inc();
            self.metrics.mid_decode_admits.inc();
            if has_work {
                slots[free] = Some(row);
            } else {
                self.backend.retire_row(session, free);
                let occupied = slots.iter().filter(|s| s.is_some()).count().max(1);
                self.cont_finish_row(st, row, false, occupied, self.clock.now_ms() - t0);
            }
        }
        Ok(())
    }

    /// Close out one row leaving the slot table: metrics, cache
    /// bookkeeping (a naturally-finished temp-0 leader seeds the cache
    /// and resolves its parked followers; a preempted leader promotes
    /// them instead), and the per-request reply.
    fn cont_finish_row(
        &mut self,
        st: &mut Continuous,
        row: SlotRow,
        preempted: bool,
        batch_size: usize,
        call_ms: f64,
    ) {
        if preempted {
            self.metrics.preempted_rows.inc();
        }
        if row.leader {
            if preempted {
                self.cont_promote(st, (row.job.kind, row.job.tokens.clone()));
            } else {
                self.cont_leader_done(st, &row.job, &row.tokens);
            }
        }
        st.resolve(
            row.route,
            GenResult {
                tokens: row.tokens,
                call_ms,
                batch_size,
                preempted,
            },
        );
    }

    /// A temp-0 leader finished its natural row: seed the cache and
    /// replay the followers parked on it (each re-cut against its own
    /// budget, zero decode steps charged — same as the round path).
    fn cont_leader_done(&mut self, st: &mut Continuous, job: &GenJob, natural: &[u32]) {
        let Some(cache) = self.cache.clone() else {
            return;
        };
        cache.insert_gen(job.kind, &job.tokens, natural, cache.generation());
        if let Some(parked) = st.followers.remove(&(job.kind, job.tokens.clone())) {
            for (fjob, fdeadline, froute) in parked {
                let result = self.replay_row(&cache, &fjob, fdeadline, Some(natural.to_vec()));
                st.resolve(froute, result);
            }
        }
    }

    /// A leader was preempted before its natural end, so its followers
    /// have nothing to replay: the first one is promoted to be the new
    /// leader (re-queued as a live job), the rest stay parked on it.
    fn cont_promote(&mut self, st: &mut Continuous, key: (GenKind, Vec<u32>)) {
        let Some(parked) = st.followers.remove(&key) else {
            return;
        };
        let mut parked = parked.into_iter();
        let Some((job, deadline, route)) = parked.next() else {
            return;
        };
        st.queue.push(job, deadline, route, true);
        st.followers.insert(key, parked.collect());
    }

    // ------------------------------------------------------------------
    // PRM scoring
    // ------------------------------------------------------------------

    /// Serve a round's PRM scoring requests as one coalesced pass: all
    /// prefixes ride shared bin-packed calls, scores scatter back per
    /// request. A backend error fails every coalesced request.
    fn prm_round(&mut self, reqs: Vec<PrmReq>) {
        if reqs.len() > 1 {
            self.metrics.coalesced_prm.add((reqs.len() - 1) as u64);
        }
        let mut batches = Vec::with_capacity(reqs.len());
        let mut replies = Vec::with_capacity(reqs.len());
        for r in reqs {
            batches.push(r.prefixes);
            replies.push(r.reply);
        }
        let (flat, bounds) = scheduler::flatten(batches);
        let outcome = self.prm_score(&flat);
        send_scattered(outcome, replies, &bounds);
    }

    fn prm_score(&mut self, prefixes: &[Vec<u32>]) -> Result<Vec<f32>> {
        let Some(cache) = self.cache.clone() else {
            return self.prm_executed(prefixes);
        };
        // Cached rows are subtracted from the batch *before* bin-
        // packing, so a round of mostly-known prefixes packs into
        // smaller buckets. Backends truncate prefixes to `prm_len`, so
        // the key does too: a longer prefix with an identical scored
        // window is still a hit.
        let stamp = cache.generation();
        let l = self.shapes.prm_len;
        let mut out: Vec<Option<f32>> = vec![None; prefixes.len()];
        let mut leader_of: HashMap<&[u32], usize> = HashMap::new();
        let mut followers: Vec<(usize, usize)> = Vec::new();
        let mut miss_rows: Vec<usize> = Vec::new();
        for (i, p) in prefixes.iter().enumerate() {
            let window = &p[..p.len().min(l)];
            if let Some(&leader) = leader_of.get(window) {
                // intra-round dedup, counted before the cache lookup
                cache.metrics.hits.inc();
                followers.push((i, leader));
            } else {
                match cache.lookup_score(&ScoreKey::Prm(window.to_vec())) {
                    Some(ScoreValue::Prm(s)) => out[i] = Some(s),
                    _ => {
                        leader_of.insert(window, i);
                        miss_rows.push(i);
                    }
                }
            }
        }
        if !miss_rows.is_empty() {
            let missing: Vec<Vec<u32>> =
                miss_rows.iter().map(|&i| prefixes[i].clone()).collect();
            let scores = self.prm_executed(&missing)?;
            for (&i, &s) in miss_rows.iter().zip(scores.iter()) {
                let window = &prefixes[i][..prefixes[i].len().min(l)];
                cache.insert_score(ScoreKey::Prm(window.to_vec()), ScoreValue::Prm(s), stamp);
                out[i] = Some(s);
            }
        }
        for (i, leader) in followers {
            out[i] = out[leader];
        }
        Ok(out
            .into_iter()
            .map(|s| s.expect("every prefix scored"))
            .collect())
    }

    /// The uncached PRM scoring path: bin-packed calls with full cost
    /// charges. With the cache enabled only the misses come through
    /// here.
    fn prm_executed(&mut self, prefixes: &[Vec<u32>]) -> Result<Vec<f32>> {
        let l = self.shapes.prm_len;
        let mut scores = Vec::with_capacity(prefixes.len());
        let bins = pack_bins(prefixes.len(), &self.shapes.batch_buckets);
        let mut start = 0usize;
        for b in bins {
            let take = b.min(prefixes.len() - start);
            let chunk = &prefixes[start..start + take];
            start += take;
            let t0 = self.clock.now_ms();
            let probs = self.backend.prm_score(b, chunk)?;
            if probs.len() < chunk.len() {
                return Err(Error::Engine(format!(
                    "backend scored {} of {} prefixes",
                    probs.len(),
                    chunk.len()
                )));
            }
            self.clock.charge(CostEvent::PrmScore { batch: b, len: l });
            self.metrics.prm_calls.inc();
            self.metrics.prm_rows.add(chunk.len() as u64);
            self.metrics.prm_padded_rows.add((b - chunk.len()) as u64);
            self.metrics
                .decode_latency
                .record(self.clock.now_ms() - t0);
            scores.extend_from_slice(&probs[..chunk.len()]);
        }
        Ok(scores)
    }

    // ------------------------------------------------------------------
    // embeddings
    // ------------------------------------------------------------------

    /// Serve a round's embedding requests coalesced per [`EmbedKind`]:
    /// same-kind queries ride shared bin-packed calls.
    fn embed_round(&mut self, reqs: Vec<EmbedReq>) {
        if reqs.len() > 1 {
            self.metrics.coalesced_embeds.add((reqs.len() - 1) as u64);
        }
        let (pool, small): (Vec<EmbedReq>, Vec<EmbedReq>) =
            reqs.into_iter().partition(|r| r.kind == EmbedKind::Pool);
        for (kind, group) in [(EmbedKind::Pool, pool), (EmbedKind::Small, small)] {
            if group.is_empty() {
                continue;
            }
            let mut batches = Vec::with_capacity(group.len());
            let mut replies = Vec::with_capacity(group.len());
            for r in group {
                batches.push(r.queries);
                replies.push(r.reply);
            }
            let (flat, bounds) = scheduler::flatten(batches);
            let outcome = self.embed(kind, &flat);
            send_scattered(outcome, replies, &bounds);
        }
    }

    fn embed(&mut self, kind: EmbedKind, queries: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        let Some(cache) = self.cache.clone() else {
            return self.embed_executed(kind, queries);
        };
        let stamp = cache.generation();
        let mut out: Vec<Option<Vec<f32>>> = vec![None; queries.len()];
        let mut leader_of: HashMap<&[u32], usize> = HashMap::new();
        let mut followers: Vec<(usize, usize)> = Vec::new();
        let mut miss_rows: Vec<usize> = Vec::new();
        for (i, q) in queries.iter().enumerate() {
            if let Some(&leader) = leader_of.get(q.as_slice()) {
                cache.metrics.hits.inc();
                followers.push((i, leader));
            } else {
                match cache.lookup_score(&ScoreKey::Embed(kind, q.clone())) {
                    Some(ScoreValue::Embed(v)) => out[i] = Some(v),
                    _ => {
                        leader_of.insert(q.as_slice(), i);
                        miss_rows.push(i);
                    }
                }
            }
        }
        if !miss_rows.is_empty() {
            let missing: Vec<Vec<u32>> =
                miss_rows.iter().map(|&i| queries[i].clone()).collect();
            let vecs = self.embed_executed(kind, &missing)?;
            for (&i, v) in miss_rows.iter().zip(vecs.into_iter()) {
                cache.insert_score(
                    ScoreKey::Embed(kind, queries[i].clone()),
                    ScoreValue::Embed(v.clone()),
                    stamp,
                );
                out[i] = Some(v);
            }
        }
        for (i, leader) in followers {
            out[i] = out[leader].clone();
        }
        Ok(out
            .into_iter()
            .map(|v| v.expect("every query embedded"))
            .collect())
    }

    /// The uncached embedding path: bin-packed calls with full cost
    /// charges. With the cache enabled only the misses come through
    /// here.
    fn embed_executed(&mut self, kind: EmbedKind, queries: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        let l = self.shapes.query_len;
        let mut out = Vec::with_capacity(queries.len());
        let bins = pack_bins(queries.len(), &self.shapes.batch_buckets);
        let mut start = 0usize;
        for b in bins {
            let take = b.min(queries.len() - start);
            let chunk = &queries[start..start + take];
            start += take;
            for q in chunk {
                if q.len() > l {
                    return Err(Error::Engine(format!(
                        "query of {} tokens exceeds query_len {l}",
                        q.len()
                    )));
                }
            }
            let vecs = self.backend.embed(kind, b, chunk)?;
            if vecs.len() < chunk.len() {
                return Err(Error::Engine(format!(
                    "backend embedded {} of {} queries",
                    vecs.len(),
                    chunk.len()
                )));
            }
            self.clock.charge(CostEvent::Embed { batch: b });
            self.metrics.embed_calls.inc();
            self.metrics.embed_rows.add(chunk.len() as u64);
            self.metrics.embed_padded_rows.add((b - chunk.len()) as u64);
            out.extend(vecs.into_iter().take(chunk.len()));
        }
        Ok(out)
    }

    fn info(&self) -> Value {
        let mut v = self.backend.describe();
        v.set("metrics", self.metrics.to_json());
        if let Some(c) = &self.cache {
            v.set("cache", c.to_json());
        }
        // the full shape contract — the engine server's handshake ack
        // forwards this object verbatim, so every field the client-side
        // EngineShapes needs must be here
        v.set(
            "shapes",
            Value::obj()
                .with("batch_buckets", self.shapes.batch_buckets.clone())
                .with("chunk_lens", self.shapes.chunk_lens.clone())
                .with("query_len", self.shapes.query_len)
                .with("prm_len", self.shapes.prm_len)
                .with("gen_max_new", self.shapes.gen_max_new)
                .with("chunk_max_new", self.shapes.chunk_max_new)
                .with("probe_fwd_batch", self.shapes.probe_fwd_batch)
                .with("probe_train_batch", self.shapes.probe_train_batch)
                .with("probe_features", self.shapes.probe_features)
                .with("d_model", self.shapes.d_model),
        );
        v
    }
}

// ---------------------------------------------------------------------
// continuous-path bookkeeping
// ---------------------------------------------------------------------

/// One in-flight `Generate` request inside a continuous run: its jobs
/// finish independently (different sessions, different steps), so the
/// reply fires exactly when the last one lands.
struct ContRequest {
    reply: std::sync::mpsc::Sender<Result<Vec<GenResult>>>,
    results: Vec<Option<GenResult>>,
    remaining: usize,
}

/// Jobs waiting for a slot, columns-of-arrays so the EDF planner and
/// [`pick_slot_admission`] can index them directly. `routes[i]` is the
/// (request, position) address of job `i`'s result; `leader[i]` marks a
/// temp-0 job other identical-prompt jobs are parked behind.
#[derive(Default)]
struct ContQueue {
    jobs: Vec<GenJob>,
    deadlines: Vec<f64>,
    routes: Vec<(usize, usize)>,
    leader: Vec<bool>,
}

impl ContQueue {
    fn push(&mut self, job: GenJob, deadline: f64, route: (usize, usize), leader: bool) {
        self.jobs.push(job);
        self.deadlines.push(deadline);
        self.routes.push(route);
        self.leader.push(leader);
    }

    /// Remove job `i`, preserving queue order (arrival order is the EDF
    /// tiebreak, so swap-remove would reorder ties).
    fn remove(&mut self, i: usize) -> (GenJob, f64, (usize, usize), bool) {
        (
            self.jobs.remove(i),
            self.deadlines.remove(i),
            self.routes.remove(i),
            self.leader.remove(i),
        )
    }

    fn len(&self) -> usize {
        self.jobs.len()
    }

    fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }
}

/// The whole state of one continuous generate run: open requests, the
/// slot-less queue, and temp-0 followers parked behind a live leader
/// keyed by (kind, prompt). `shutdown` stops further message intake
/// while accepted work finishes.
struct Continuous {
    requests: Vec<ContRequest>,
    queue: ContQueue,
    followers: HashMap<(GenKind, Vec<u32>), Vec<(GenJob, f64, (usize, usize))>>,
    shutdown: bool,
}

impl Continuous {
    /// Land one job's result; replies to the owning request when it was
    /// the last one outstanding.
    fn resolve(&mut self, route: (usize, usize), result: GenResult) {
        let req = &mut self.requests[route.0];
        debug_assert!(req.results[route.1].is_none(), "row resolved twice");
        req.results[route.1] = Some(result);
        req.remaining -= 1;
        if req.remaining == 0 {
            let results = req
                .results
                .iter_mut()
                .map(|r| r.take().expect("remaining hit zero with a hole"))
                .collect();
            let _ = req.reply.send(Ok(results));
        }
    }
}

/// One occupied row of a session's slot table.
struct SlotRow {
    job: GenJob,
    deadline_ms: f64,
    route: (usize, usize),
    leader: bool,
    /// `max_new_tokens` cap (usize::MAX when uncapped).
    cap: usize,
    tokens: Vec<u32>,
}

// ---------------------------------------------------------------------
// DeviceBackend: the PJRT execution path
// ---------------------------------------------------------------------

/// Probe training state held on the engine thread.
struct ProbeState {
    /// Flat params in manifest order.
    params: Vec<f32>,
    /// Tensor boundaries (shapes + offsets) from the probe manifest.
    entries: Vec<crate::runtime::weights::WeightEntry>,
    /// Cached device literals of `params` in manifest order — rebuilt
    /// lazily after [`ProbeState::set_params`] invalidates them, so the
    /// `probe_fwd` hot path stops re-uploading every parameter tensor
    /// on every chunk.
    literals: Option<Vec<xla::Literal>>,
}

impl ProbeState {
    /// Replace the parameters, invalidating the cached device literals.
    /// Every write to `params` must go through here.
    fn set_params(&mut self, params: Vec<f32>) {
        self.params = params;
        self.literals = None;
    }

    /// The cached param literals, building them on first use. Returned
    /// mutably so the caller can push the per-call activation literal
    /// and pop it again — append-only borrowing, never a rebuild.
    fn literals(&mut self) -> Result<&mut Vec<xla::Literal>> {
        if self.literals.is_none() {
            let lits = self
                .entries
                .iter()
                .map(|e| {
                    let data = &self.params[e.offset..e.offset + e.size];
                    if e.shape.is_empty() {
                        Ok(xla::Literal::scalar(data[0]))
                    } else {
                        crate::runtime::literals::f32_tensor(data, &e.shape)
                    }
                })
                .collect::<Result<Vec<_>>>()?;
            self.literals = Some(lits);
        }
        Ok(self.literals.as_mut().expect("just built"))
    }
}

/// Reusable host staging arenas for padded device-call inputs. Capacity
/// grows to the largest bucket seen and is then reused — `clear` +
/// `resize` never shrink a `Vec`, so the steady-state hot path performs
/// zero host allocations for token/len/feature blocks.
#[derive(Default)]
struct Staging {
    tokens: Vec<i32>,
    lens: Vec<i32>,
    feats: Vec<f32>,
}

impl Staging {
    /// Reset the token block to `b × l` zeros and lens to `b` ones (the
    /// padding-row defaults every call site wants).
    fn reset(&mut self, b: usize, l: usize) {
        self.tokens.clear();
        self.tokens.resize(b * l, 0);
        self.lens.clear();
        self.lens.resize(b, 1);
    }

    /// Reset the feature block to `n` zeros.
    fn reset_feats(&mut self, n: usize) {
        self.feats.clear();
        self.feats.resize(n, 0.0);
    }
}

/// The PJRT device execution path: AOT'd executables, device-resident
/// weights, host staging arenas. `!Send` by construction (the `xla`
/// crate's handles are `Rc`-based), which is why backends are built *on*
/// the engine thread via [`crate::engine::backend::BackendFactory`].
pub struct DeviceBackend {
    execs: ExecutableSet,
    lm_bufs: Vec<xla::PjRtBuffer>,
    probe: ProbeState,
    staging: Staging,
    shapes: EngineShapes,
    clock: SharedClock,
    rng: Rng,
}

impl DeviceBackend {
    /// Load artifacts and upload weights. `stream` differentiates the
    /// RNG stream per pool member (member 0 matches the historical
    /// single-engine stream exactly).
    pub fn new(
        artifacts: &PathBuf,
        clock: SharedClock,
        seed: u64,
        stream: u64,
    ) -> Result<DeviceBackend> {
        let execs = ExecutableSet::new(artifacts)?;
        let shapes = EngineShapes::from_meta(&execs.index().meta)?;

        // the PRM is likelihood-based over the generator weights, so the
        // engine holds exactly two weight sets: the LM and the probe.
        let lm = WeightSet::load(artifacts, "lm")?;
        let probe_ws = WeightSet::load(artifacts, "probe")?;
        log_info!(
            "engine: weights lm={} tensors, probe={} ({} f32)",
            lm.len(),
            probe_ws.len(),
            probe_ws.blob.len()
        );

        let client = execs.client().clone();
        let upload = |ws: &WeightSet| -> Result<Vec<xla::PjRtBuffer>> {
            ws.entries
                .iter()
                .enumerate()
                .map(|(i, e)| {
                    let dims: Vec<usize> = if e.shape.is_empty() {
                        vec![]
                    } else {
                        e.shape.clone()
                    };
                    client
                        .buffer_from_host_buffer::<f32>(ws.tensor_data(i), &dims, None)
                        .map_err(Error::from)
                })
                .collect()
        };
        let lm_bufs = upload(&lm)?;

        Ok(DeviceBackend {
            execs,
            lm_bufs,
            probe: ProbeState {
                params: probe_ws.blob.clone(),
                entries: probe_ws.entries.clone(),
                literals: None,
            },
            staging: Staging::default(),
            shapes,
            clock,
            rng: Rng::new(seed, 0xE17 + stream),
        })
    }
}

impl Backend for DeviceBackend {
    fn name(&self) -> &'static str {
        "device"
    }

    fn shapes(&self) -> &EngineShapes {
        &self.shapes
    }

    fn describe(&self) -> Value {
        Value::obj()
            .with("backend", "device")
            .with("platform", self.execs.client().platform_name())
            .with("compile_ms_total", self.execs.total_compile_ms())
    }

    fn generate(&mut self, plan: &BatchPlan, prompts: &[&[u32]]) -> Result<Vec<Vec<u32>>> {
        let exec_name = match plan.kind {
            GenKind::Full => format!("lm_generate_b{}", plan.bucket),
            GenKind::Chunk => format!("lm_chunk_b{}_l{}", plan.bucket, plan.len_bucket),
        };
        let exe = self.execs.get(&exec_name)?;

        // assemble the padded token block in the reusable staging
        // arena; padding rows get a 1-token prompt
        let b = plan.bucket;
        let l = plan.len_bucket;
        self.staging.reset(b, l);
        for (row, t) in prompts.iter().enumerate() {
            for (c, &id) in t.iter().enumerate() {
                self.staging.tokens[row * l + c] = id as i32;
            }
            self.staging.lens[row] = t.len() as i32;
        }
        for row in prompts.len()..b {
            self.staging.tokens[row * l] = 19; // 'Q' — dummy prompt for padding rows
        }
        let key = [self.rng.next_u32(), self.rng.next_u32()];

        let client = self.execs.client().clone();
        let tok_buf = client.buffer_from_host_buffer::<i32>(&self.staging.tokens, &[b, l], None)?;
        let len_buf = client.buffer_from_host_buffer::<i32>(&self.staging.lens, &[b], None)?;
        let key_buf = client.buffer_from_host_buffer::<u32>(&key, &[2], None)?;
        let temp_buf = client.buffer_from_host_buffer::<f32>(&[plan.temperature], &[], None)?;

        let mut args: Vec<&xla::PjRtBuffer> = self.lm_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        args.push(&key_buf);
        args.push(&temp_buf);
        let out = exe.run_buffers(&args)?;
        let tuple = out
            .first()
            .ok_or_else(|| Error::Engine("empty generate output".into()))?
            .to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != 2 {
            return Err(Error::Engine(format!(
                "generate returned {} outputs, expected 2",
                parts.len()
            )));
        }
        let gen: Vec<i32> = parts[0].to_vec()?;
        let gen_len: Vec<i32> = parts[1].to_vec()?;
        let t_cols = gen.len() / b;

        Ok((0..prompts.len())
            .map(|row| {
                let natural_len = (gen_len[row] as usize).min(t_cols);
                gen[row * t_cols..row * t_cols + natural_len]
                    .iter()
                    .map(|&t| t as u32)
                    .collect()
            })
            .collect())
    }

    fn prm_score(&mut self, b: usize, prefixes: &[Vec<u32>]) -> Result<Vec<f32>> {
        let l = self.shapes.prm_len;
        let exe = self.execs.get(&format!("prm_score_b{b}"))?;
        self.staging.reset(b, l);
        for (row, p) in prefixes.iter().enumerate() {
            let n = p.len().min(l);
            for (c, &id) in p[..n].iter().enumerate() {
                self.staging.tokens[row * l + c] = id as i32;
            }
            self.staging.lens[row] = n as i32;
        }
        for row in prefixes.len()..b {
            self.staging.tokens[row * l] = 19;
        }
        let client = self.execs.client().clone();
        let tok_buf = client.buffer_from_host_buffer::<i32>(&self.staging.tokens, &[b, l], None)?;
        let len_buf = client.buffer_from_host_buffer::<i32>(&self.staging.lens, &[b], None)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.lm_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        let out = exe.run_buffers(&args)?;
        let tuple = out
            .first()
            .ok_or_else(|| Error::Engine("empty prm output".into()))?
            .to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        let probs: Vec<f32> = parts[0].to_vec()?;
        Ok(probs[..prefixes.len()].to_vec())
    }

    fn embed(&mut self, kind: EmbedKind, b: usize, queries: &[Vec<u32>]) -> Result<Vec<Vec<f32>>> {
        let l = self.shapes.query_len;
        let d = self.shapes.d_model;
        let prefix = match kind {
            EmbedKind::Pool => "embed_pool",
            EmbedKind::Small => "embed_small",
        };
        let exe = self.execs.get(&format!("{prefix}_b{b}"))?;
        self.staging.reset(b, l);
        for (row, q) in queries.iter().enumerate() {
            for (c, &id) in q.iter().enumerate() {
                self.staging.tokens[row * l + c] = id as i32;
            }
            self.staging.lens[row] = q.len() as i32;
        }
        for row in queries.len()..b {
            self.staging.tokens[row * l] = 19;
        }
        let client = self.execs.client().clone();
        let tok_buf = client.buffer_from_host_buffer::<i32>(&self.staging.tokens, &[b, l], None)?;
        let len_buf = client.buffer_from_host_buffer::<i32>(&self.staging.lens, &[b], None)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.lm_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        let result = exe.run_buffers(&args)?;
        let tuple = result
            .first()
            .ok_or_else(|| Error::Engine("empty embed output".into()))?
            .to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        let flat: Vec<f32> = parts[0].to_vec()?;
        Ok((0..queries.len())
            .map(|row| flat[row * d..(row + 1) * d].to_vec())
            .collect())
    }

    fn probe_fwd(&mut self, feats: &[Vec<f32>]) -> Result<Vec<f32>> {
        let b = self.shapes.probe_fwd_batch;
        let f = self.shapes.probe_features;
        let exe = self.execs.get(&format!("probe_fwd_b{b}"))?;
        let mut out = Vec::with_capacity(feats.len());
        for chunk in feats.chunks(b) {
            self.staging.reset_feats(b * f);
            for (row, feat) in chunk.iter().enumerate() {
                if feat.len() != f {
                    return Err(Error::Engine(format!(
                        "feature row has {} dims, probe expects {f}",
                        feat.len()
                    )));
                }
                self.staging.feats[row * f..(row + 1) * f].copy_from_slice(feat);
            }
            let block = crate::runtime::literals::f32_tensor(&self.staging.feats, &[b, f])?;
            // cached param literals + this chunk's activation block;
            // popped right back so the cache only ever holds params
            let args = self.probe.literals()?;
            args.push(block);
            let ran = exe.run_literals(args);
            args.pop();
            let parts = ran?;
            let logits: Vec<f32> = parts[0].to_vec()?;
            self.clock.charge(CostEvent::Probe { batch: b });
            out.extend_from_slice(&logits[..chunk.len()]);
        }
        Ok(out)
    }

    fn probe_train(
        &mut self,
        train_feats: &[Vec<f32>],
        train_labels: &[f32],
        val_feats: &[Vec<f32>],
        val_labels: &[f32],
        epochs: usize,
        patience: usize,
    ) -> Result<ProbeTrainReport> {
        let bsz = self.shapes.probe_train_batch;
        let f = self.shapes.probe_features;
        if train_feats.len() != train_labels.len() {
            return Err(Error::Engine("train feats/labels length mismatch".into()));
        }
        let exe = self.execs.get(&format!("probe_train_b{bsz}"))?;

        // state: params, m, v as flat blobs
        let n_tensors = self.probe.entries.len();
        let mut params = self.probe.params.clone();
        let mut m = vec![0f32; params.len()];
        let mut v = vec![0f32; params.len()];

        let to_literals = |blob: &[f32],
                           entries: &[crate::runtime::weights::WeightEntry]|
         -> Result<Vec<xla::Literal>> {
            entries
                .iter()
                .map(|e| {
                    let data = &blob[e.offset..e.offset + e.size];
                    if e.shape.is_empty() {
                        Ok(xla::Literal::scalar(data[0]))
                    } else {
                        crate::runtime::literals::f32_tensor(data, &e.shape)
                    }
                })
                .collect()
        };

        let mut order: Vec<usize> = (0..train_feats.len()).collect();
        let mut step = 0usize;
        let mut best_val = f64::INFINITY;
        let mut best_params = params.clone();
        let mut bad_epochs = 0usize;
        let mut curve = Vec::new();
        let mut last_train_loss = 0.0f64;

        for epoch in 0..epochs {
            self.rng.shuffle(&mut order);
            let mut epoch_losses = Vec::new();
            for batch_idx in order.chunks(bsz) {
                step += 1;
                let mut feats_block = vec![0f32; bsz * f];
                let mut labels_block = vec![0f32; bsz];
                for (row, &i) in batch_idx.iter().enumerate() {
                    feats_block[row * f..(row + 1) * f].copy_from_slice(&train_feats[i]);
                    labels_block[row] = train_labels[i];
                }
                // wrap-fill the remainder rows so gradients stay unbiased-ish
                for row in batch_idx.len()..bsz {
                    let i = order[(row + step) % order.len()];
                    feats_block[row * f..(row + 1) * f].copy_from_slice(&train_feats[i]);
                    labels_block[row] = train_labels[i];
                }

                let mut args = to_literals(&params, &self.probe.entries)?;
                args.extend(to_literals(&m, &self.probe.entries)?);
                args.extend(to_literals(&v, &self.probe.entries)?);
                args.push(xla::Literal::scalar(step as f32));
                args.push(crate::runtime::literals::f32_tensor(&feats_block, &[bsz, f])?);
                args.push(crate::runtime::literals::f32_tensor(&labels_block, &[bsz])?);

                let parts = exe.run_literals(&args)?;
                if parts.len() != 3 * n_tensors + 1 {
                    return Err(Error::Engine(format!(
                        "probe_train returned {} outputs, expected {}",
                        parts.len(),
                        3 * n_tensors + 1
                    )));
                }
                let write = |blob: &mut Vec<f32>, offset: usize| -> Result<()> {
                    for (ti, e) in self.probe.entries.iter().enumerate() {
                        let data: Vec<f32> = parts[offset + ti].to_vec()?;
                        blob[e.offset..e.offset + e.size].copy_from_slice(&data);
                    }
                    Ok(())
                };
                write(&mut params, 0)?;
                write(&mut m, n_tensors)?;
                write(&mut v, 2 * n_tensors)?;
                let loss: f32 = parts[3 * n_tensors].get_first_element()?;
                epoch_losses.push(loss as f64);
                self.clock.charge(CostEvent::Probe { batch: bsz });
            }
            last_train_loss = stats::mean(&epoch_losses);

            // validation loss with current params (set_params keeps the
            // literal cache honest across the swap in and back)
            let saved = std::mem::take(&mut self.probe.params);
            self.probe.set_params(params.clone());
            let val_fwd = self.probe_fwd(val_feats);
            self.probe.set_params(saved);
            let val_logits = val_fwd?;
            let val_loss = val_logits
                .iter()
                .zip(val_labels)
                .map(|(&z, &y)| stats::bce(y as f64, stats::sigmoid(z as f64)))
                .sum::<f64>()
                / val_labels.len().max(1) as f64;
            curve.push((epoch, last_train_loss, val_loss));
            log_debug!(
                "probe epoch {epoch}: train {last_train_loss:.4} val {val_loss:.4}"
            );

            if val_loss < best_val - 1e-6 {
                best_val = val_loss;
                best_params = params.clone();
                bad_epochs = 0;
            } else {
                bad_epochs += 1;
                if bad_epochs > patience {
                    log_info!("probe early stop at epoch {epoch} (best val {best_val:.4})");
                    break;
                }
            }
        }

        self.probe.set_params(best_params.clone());
        Ok(ProbeTrainReport {
            steps: step,
            final_train_loss: last_train_loss,
            best_val_loss: best_val,
            curve,
            params: best_params,
        })
    }

    fn probe_load(&mut self, params: Vec<f32>) -> Result<()> {
        if params.len() != self.probe.params.len() {
            return Err(Error::Engine(format!(
                "probe blob has {} params, expected {}",
                params.len(),
                self.probe.params.len()
            )));
        }
        self.probe.set_params(params);
        Ok(())
    }

    fn stepping(&self) -> bool {
        true
    }

    fn prefill(&mut self, plan: &BatchPlan, prompts: &[&[u32]]) -> Result<DecodeSession> {
        let chunked = self.chunked_decode_available(plan);
        let rows: Vec<DevRow> = if chunked {
            // first segment only — continuation segments run on demand
            // between engine steps, so retirement/admission change what
            // the device actually computes next
            let firsts = self.run_segment(plan.temperature, prompts)?;
            prompts
                .iter()
                .zip(firsts)
                .map(|(p, buf)| self.dev_row(p.to_vec(), buf, false))
                .collect()
        } else {
            // one in-graph call computes the whole natural row (the
            // single-sample contract for temp>0, and the only option
            // when no chunk bucket covers the composed prefix length)
            self.generate(plan, prompts)?
                .into_iter()
                .zip(prompts)
                .map(|(buf, p)| self.dev_row(p.to_vec(), buf, true))
                .collect()
        };
        let mut slots: Vec<Option<DevRow>> = (0..plan.bucket).map(|_| None).collect();
        let mut empty = Vec::new();
        for (slot, row) in rows.into_iter().enumerate() {
            if row.ended && row.buf.is_empty() {
                empty.push(slot);
            } else {
                slots[slot] = Some(row);
            }
        }
        let mut session =
            DecodeSession::new(plan, Box::new(DeviceSession { rows: slots, chunked }));
        session.empty_rows = empty;
        Ok(session)
    }

    fn decode_step(&mut self, session: &mut DecodeSession) -> Result<StepRows> {
        let temperature = session.temperature;
        let state: &mut DeviceSession = session.state_mut()?;
        // rows at a segment boundary (or holding only their final
        // buffered token) need the next chunk before this step can tell
        // the engine whether that token is the last one
        let needs: Vec<usize> = state
            .rows
            .iter()
            .enumerate()
            .filter_map(|(slot, r)| match r {
                Some(r) if !r.ended && r.cursor + 1 >= r.buf.len() => Some(slot),
                _ => None,
            })
            .collect();
        if !needs.is_empty() {
            let prefixes: Vec<Vec<u32>> = needs
                .iter()
                .map(|&s| {
                    let r = state.rows[s].as_ref().expect("selected above");
                    let mut p = r.prompt.clone();
                    p.extend_from_slice(&r.buf);
                    p
                })
                .collect();
            let refs: Vec<&[u32]> = prefixes.iter().map(|p| p.as_slice()).collect();
            let segments = self.run_segment(temperature, &refs)?;
            let state: &mut DeviceSession = session.state_mut()?;
            for (&slot, seg) in needs.iter().zip(segments) {
                let row = state.rows[slot].as_mut().expect("selected above");
                row.extend(seg, self.shapes.gen_max_new, self.shapes.chunk_max_new);
            }
        }
        let state: &mut DeviceSession = session.state_mut()?;
        Ok(state
            .rows
            .iter_mut()
            .map(|r| r.as_mut().and_then(DevRow::step))
            .collect())
    }

    fn admit_row(&mut self, session: &mut DecodeSession, slot: usize, prompt: &[u32]) -> Result<bool> {
        let chunked = session.state_mut::<DeviceSession>()?.chunked;
        let row = if chunked {
            let buf = self
                .run_segment(session.temperature, &[prompt])?
                .remove(0);
            self.dev_row(prompt.to_vec(), buf, false)
        } else {
            let plan = BatchPlan {
                job_indices: vec![0],
                bucket: 1,
                len_bucket: session.len_bucket,
                kind: session.kind,
                temperature: session.temperature,
                max_steps: None,
            };
            let buf = self.generate(&plan, &[prompt])?.remove(0);
            self.dev_row(prompt.to_vec(), buf, true)
        };
        let state: &mut DeviceSession = session.state_mut()?;
        match state.rows.get_mut(slot) {
            Some(free @ None) => {
                if row.ended && row.buf.is_empty() {
                    return Ok(false);
                }
                *free = Some(row);
                Ok(true)
            }
            Some(Some(_)) => Err(Error::Engine(format!("slot {slot} already occupied"))),
            None => Err(Error::Engine(format!("slot {slot} out of range"))),
        }
    }

    fn retire_row(&mut self, session: &mut DecodeSession, slot: usize) -> usize {
        // retiring drops the row from every future segment call — the
        // compute genuinely stops — but the device cannot know how many
        // steps the unseen natural tail would have taken, so it reports
        // none rather than guess
        if let Ok(state) = session.state_mut::<DeviceSession>() {
            if let Some(r) = state.rows.get_mut(slot) {
                r.take();
            }
        }
        0
    }
}

// ---------------------------------------------------------------------
// DeviceBackend native stepping
// ---------------------------------------------------------------------

/// Session state for the device backend's native stepping. The device
/// executables decode in-graph, so "stepping" means **chunked decode**:
/// temp-0 full generation runs as a sequence of `lm_chunk` continuation
/// segments (each over prompt + tokens-so-far, staged through the same
/// reusable arenas as every other call), with tokens replayed to the
/// engine one step at a time between segments. Retiring a row really
/// does stop its compute — it is simply absent from every later segment
/// call. Sampled (temp>0) and chunk-kind sessions stay single-call
/// buffered: re-sampling a continuation would change the distribution
/// the round path defines, so their one in-graph call *is* the
/// contract.
struct DeviceSession {
    rows: Vec<Option<DevRow>>,
    /// Whether rows decode via continuation segments (temp-0 full
    /// generation with chunk-length coverage) or were fully buffered at
    /// prefill.
    chunked: bool,
}

/// One device session row: the growing computed continuation (`buf`)
/// and the engine-facing replay cursor. `ended` means the natural end
/// is *known* — a segment came back short of `chunk_max_new`, the
/// total hit `gen_max_new`, or the row was fully buffered at prefill.
struct DevRow {
    prompt: Vec<u32>,
    buf: Vec<u32>,
    cursor: usize,
    ended: bool,
}

impl DevRow {
    /// Absorb one continuation segment's fresh tokens.
    fn extend(&mut self, seg: Vec<u32>, gen_max_new: usize, chunk_max_new: usize) {
        let seg_len = seg.len();
        self.buf.extend(seg);
        if self.buf.len() >= gen_max_new {
            self.buf.truncate(gen_max_new);
            self.ended = true;
        } else if seg_len < chunk_max_new {
            // the segment stopped before its capacity: EOS inside it
            self.ended = true;
        }
    }

    fn step(&mut self) -> Option<StepTok> {
        if self.cursor >= self.buf.len() {
            return None;
        }
        let token = self.buf[self.cursor];
        self.cursor += 1;
        Some(StepTok {
            token,
            // decode_step ran a segment for any non-ended row down to
            // its final buffered token, so `ended` is decided by the
            // time that token is handed out
            last: self.ended && self.cursor == self.buf.len(),
        })
    }
}

impl DeviceBackend {
    /// Whether this plan can decode via continuation segments: greedy
    /// full generation only (a re-sampled continuation is a different
    /// draw), with a chunk length bucket wide enough for the longest
    /// possible composed prefix, so every mid-session segment is
    /// guaranteed an executable. `chunk_max_new >= 2` keeps the
    /// final-token hold-back invariant (a 1-token segment could
    /// otherwise leave a row's last token unflagged).
    fn chunked_decode_available(&self, plan: &BatchPlan) -> bool {
        plan.temperature == 0.0
            && plan.kind == GenKind::Full
            && self.shapes.chunk_max_new >= 2
            && {
                let need = plan.len_bucket + self.shapes.gen_max_new;
                self.shapes.chunk_lens.iter().any(|&x| x >= need)
            }
    }

    /// One batched continuation segment: each prefix is a row's prompt
    /// plus everything generated so far; returns the fresh tokens per
    /// row. Rides the ordinary chunk executables (and the staging
    /// arenas) through `generate`.
    fn run_segment(&mut self, temperature: f32, prefixes: &[&[u32]]) -> Result<Vec<Vec<u32>>> {
        let n = prefixes.len();
        let b = self
            .shapes
            .batch_buckets
            .iter()
            .copied()
            .filter(|&x| x >= n)
            .min()
            .ok_or_else(|| Error::Engine(format!("no batch bucket covers {n} segment rows")))?;
        let need = prefixes.iter().map(|p| p.len()).max().unwrap_or(1).max(1);
        let l = self
            .shapes
            .chunk_lens
            .iter()
            .copied()
            .filter(|&x| x >= need)
            .min()
            .ok_or_else(|| {
                Error::Engine(format!("no chunk length bucket covers a {need}-token prefix"))
            })?;
        let plan = BatchPlan {
            job_indices: (0..n).collect(),
            bucket: b,
            len_bucket: l,
            kind: GenKind::Chunk,
            temperature,
            max_steps: None,
        };
        self.generate(&plan, prefixes)
    }

    /// Build a session row. `buffered` rows hold their whole natural
    /// output (ended by construction); segment-fed rows absorb their
    /// first segment through the same cap/EOS logic as later ones.
    fn dev_row(&self, prompt: Vec<u32>, buf: Vec<u32>, buffered: bool) -> DevRow {
        let mut row = DevRow {
            prompt,
            buf: Vec::new(),
            cursor: 0,
            ended: buffered,
        };
        if buffered {
            row.buf = buf;
        } else {
            row.extend(buf, self.shapes.gen_max_new, self.shapes.chunk_max_new);
        }
        row
    }
}

// ---------------------------------------------------------------------
// tests
// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::EngineConfig;
    use crate::engine::backend::SimBackend;
    use crate::tokenizer::Tokenizer;
    use crate::util::clock;
    use std::sync::mpsc::channel;

    fn sim_thread(seed: u64, stream: u64, continuous: bool) -> EngineThread {
        let clock = clock::sim_clock();
        let backend = Box::new(SimBackend::new(
            EngineShapes::sim_default(&EngineConfig::default()),
            clock.clone(),
            seed,
            stream,
        ));
        EngineThread::new(backend, clock, Arc::new(EngineMetrics::new()))
            .with_continuous(continuous)
    }

    fn job(tok: &Tokenizer, text: &str) -> GenJob {
        GenJob::new(tok.encode(text).unwrap(), GenKind::Full, 0.0)
    }

    /// Temp-0 reference row: what a fresh solo engine generates for the
    /// prompt (pure function of the prompt, so any seed/stream works).
    fn solo(tok: &Tokenizer, text: &str) -> Vec<u32> {
        let shapes = EngineShapes::sim_default(&EngineConfig::default());
        let query_len = shapes.query_len;
        let mut b = SimBackend::new(shapes, clock::sim_clock(), 99, 5);
        let plan = BatchPlan {
            job_indices: vec![0],
            bucket: 1,
            len_bucket: query_len,
            kind: GenKind::Full,
            temperature: 0.0,
            max_steps: None,
        };
        let prompt = tok.encode(text).unwrap();
        b.generate(&plan, &[&prompt]).unwrap().remove(0)
    }

    /// With no mid-decode arrivals, the continuous path must be
    /// byte-identical to the round path — same tokens, same preemption
    /// verdicts, and the same sim-clock cost sequence (charge
    /// equivalence), cap cuts included.
    #[test]
    fn continuous_quiet_run_matches_round_path() {
        let tok = Tokenizer::new();
        let prompts = ["Q:7+8-5=?\nS:", "Q:2*3+4=?\nS:", "Q:9-2*3=?\nS:"];
        let run = |continuous: bool| {
            let mut t = sim_thread(7, 0, continuous);
            let mut jobs: Vec<GenJob> = prompts.iter().map(|p| job(&tok, p)).collect();
            jobs[1] = jobs.remove(1).with_max_new_tokens(4);
            let (reply, rx) = channel();
            let req = GenerateReq {
                jobs,
                deadline_ms: None,
                reply,
            };
            if continuous {
                assert!(t.continuous_active(), "sim backend steps natively");
                t.generate_continuous(vec![req], &mut || None, false);
            } else {
                t.generate_merged(vec![req]);
            }
            let results = rx.recv().unwrap().unwrap();
            (results, t.clock.now_ms())
        };
        let (cont, cont_ms) = run(true);
        let (round, round_ms) = run(false);
        assert_eq!(cont.len(), round.len());
        for (c, r) in cont.iter().zip(&round) {
            assert_eq!(c.tokens, r.tokens, "temp-0 byte equivalence");
            assert_eq!(c.preempted, r.preempted);
        }
        assert!(cont[1].preempted, "cap 4 must cut row 1");
        assert_eq!(cont[1].tokens.len(), 4);
        assert_eq!(
            cont_ms, round_ms,
            "identical charge sequence on the sim clock"
        );
    }

    /// A row whose deadline expires mid-decode is retired between steps
    /// (step-granular, no proration), its slot is re-used by a job that
    /// arrives mid-session, and the freed decode steps are recorded.
    #[test]
    fn deadline_cut_frees_slot_for_mid_decode_admit() {
        let tok = Tokenizer::new();
        let (a_text, b_text, e_text) = ("Q:7+8-5=?\nS:", "Q:2*3+4=?\nS:", "Q:9-2*3=?\nS:");
        let solo_a = solo(&tok, a_text);
        let solo_b = solo(&tok, b_text);
        let solo_e = solo(&tok, e_text);
        assert!(solo_a.len() > 4 && solo_b.len() > 6, "need a long decode");

        let mut t = sim_thread(7, 0, true);
        // place the deadline 2.5 decode steps past the batch-2 prefill,
        // measured on a scratch clock with the same latency model
        let probe = clock::sim_clock();
        probe.charge(CostEvent::Prefill {
            batch: 2,
            len: t.shapes.query_len,
        });
        let p = probe.now_ms();
        probe.charge(CostEvent::DecodeStep { batch: 2 });
        let s = probe.now_ms() - p;
        let deadline = p + 2.5 * s;

        // A and B are separate requests sharing one planned session:
        // only A carries the tight deadline, so B keeps the session
        // alive after A is cut and the freed slot is observable
        let (reply_a, rx_a) = channel();
        let req_a = GenerateReq {
            jobs: vec![job(&tok, a_text)],
            deadline_ms: Some(deadline),
            reply: reply_a,
        };
        let (reply_b, rx_b) = channel();
        let req_b = GenerateReq {
            jobs: vec![job(&tok, b_text)],
            deadline_ms: None,
            reply: reply_b,
        };
        let (reply_e, rx_e) = channel();
        let mut pending = Some(EngineMsg::Generate {
            jobs: vec![job(&tok, e_text)],
            deadline_ms: None,
            reply: reply_e,
        });
        t.generate_continuous(vec![req_a, req_b], &mut || pending.take(), false);

        let a = rx_a.recv().unwrap().unwrap();
        let b = rx_b.recv().unwrap().unwrap();
        let e = rx_e.recv().unwrap().unwrap();
        // A was cut between steps the moment the clock crossed its
        // deadline — a true prefix of its natural row, no proration
        assert!(a[0].preempted, "A's deadline expired mid-decode");
        assert!(!a[0].tokens.is_empty(), "deadline allowed ~2.5 steps");
        assert!(a[0].tokens.len() < solo_a.len(), "cut short of natural end");
        assert_eq!(a[0].tokens, solo_a[..a[0].tokens.len()], "prefix purity");
        // B never had a deadline: untouched by A's preemption
        assert_eq!(b[0].tokens, solo_b);
        assert!(!b[0].preempted);
        // E arrived mid-session, took A's freed slot, ran to its end
        assert_eq!(e[0].tokens, solo_e, "admitted row matches a solo run");
        assert!(!e[0].preempted);
        assert_eq!(t.metrics.mid_decode_admits.get(), 1);
        assert!(t.metrics.retired_rows.get() >= 3);
        assert!(
            t.metrics.decode_steps_saved_live.get() >= 1,
            "retiring A mid-decode must free real steps"
        );
        assert!(t.metrics.slot_occupancy() > 0.0);
    }

    /// A `Generate` that arrives while a session is stepping joins the
    /// run and is answered without waiting for the next scheduling
    /// round — through a freed slot if one opens (a row finishing its
    /// natural decode frees one too), or a follow-up session otherwise.
    #[test]
    fn straggler_generate_is_served_within_the_run() {
        let tok = Tokenizer::new();
        let solo_e = solo(&tok, "Q:9-2*3=?\nS:");
        let mut t = sim_thread(7, 0, true);
        let (reply_ab, rx_ab) = channel();
        let req = GenerateReq {
            jobs: vec![job(&tok, "Q:7+8-5=?\nS:"), job(&tok, "Q:2*3+4=?\nS:")],
            deadline_ms: None,
            reply: reply_ab,
        };
        let (reply_e, rx_e) = channel();
        let mut pending = Some(EngineMsg::Generate {
            jobs: vec![job(&tok, "Q:9-2*3=?\nS:")],
            deadline_ms: None,
            reply: reply_e,
        });
        t.generate_continuous(vec![req], &mut || pending.take(), false);
        let ab = rx_ab.recv().unwrap().unwrap();
        assert!(ab.iter().all(|r| !r.preempted));
        let e = rx_e.recv().unwrap().unwrap();
        assert_eq!(e[0].tokens, solo_e);
        assert!(!e[0].preempted);
        assert!(t.metrics.coalesced_generates.get() >= 1);
    }
}
