//! Mid-call preemption: the engine's decode accounting loop.
//!
//! Generation itself is in-graph (one executable call produces every
//! token), but *time* flows through the [`Clock`] one decode step at a
//! time. This module walks those steps and halts individual rows the
//! moment their budget runs out — deadline passed, cancel flag flipped,
//! or per-job token cap reached — so a single batched call returns
//! partial results instead of blowing through a deadline. Under the
//! simulated clock this gives exact per-step preemption; under the real
//! clock the charges are no-ops and preemption granularity degrades to
//! per-call (the call has already happened), which the module documents
//! rather than hides.
//!
//! Pure logic over a [`Clock`] — unit-testable without PJRT.

use crate::util::clock::{Clock, CostEvent};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// One row's budget within a batched call.
#[derive(Debug, Clone)]
pub struct RowBudget {
    /// Tokens the executable naturally produced for this row.
    pub natural_len: usize,
    /// Per-job cap on new tokens (`usize::MAX` when uncapped).
    pub cap: usize,
    /// Absolute engine-clock deadline in ms (`f64::INFINITY` when none).
    pub deadline_ms: f64,
    /// Shared cooperative cancel flag.
    pub cancel: Option<Arc<AtomicBool>>,
    /// Secondary job-subset stop flag ([`crate::engine::GenJob::stop`]);
    /// either flag halts the row.
    pub stop: Option<Arc<AtomicBool>>,
}

impl RowBudget {
    /// Natural length bounded by the token cap.
    fn target(&self) -> usize {
        self.natural_len.min(self.cap)
    }

    pub(crate) fn halted(&self, now_ms: f64) -> bool {
        let up = |f: &Option<Arc<AtomicBool>>| {
            f.as_ref().is_some_and(|f| f.load(Ordering::Relaxed))
        };
        now_ms >= self.deadline_ms || up(&self.cancel) || up(&self.stop)
    }
}

/// Where the accounting loop cut one row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RowCut {
    /// Tokens of this row that count as generated (prefix length).
    pub emitted: usize,
    /// The row was halted before its natural end (deadline, cancel, or
    /// token cap).
    pub preempted: bool,
}

/// Walk a batched call's decode steps on the clock, charging one
/// [`CostEvent::DecodeStep`] per step at `batch` rows, and halting rows
/// whose budget runs out between steps. `max_steps` is the call-level
/// ceiling planned by [`crate::engine::batcher::plan_batches`] (the
/// largest per-row cap, or `None` when any row is uncapped) — no step
/// is charged past it. Returns per-row cuts plus the number of steps
/// actually charged.
///
/// Invariants (property-tested below):
/// * `emitted ≤ min(natural_len, cap)` for every row;
/// * a row is `preempted` iff it emitted fewer tokens than
///   `natural_len`;
/// * steps charged = max emitted over rows, and ≤ `max_steps`.
pub fn run_decode_accounting(
    clock: &dyn Clock,
    batch: usize,
    rows: &[RowBudget],
    max_steps: Option<usize>,
) -> (Vec<RowCut>, usize) {
    let mut cuts: Vec<RowCut> = rows
        .iter()
        .map(|_| RowCut {
            emitted: 0,
            preempted: false,
        })
        .collect();
    let mut steps = 0usize;
    loop {
        if max_steps.is_some_and(|cap| steps >= cap) {
            break;
        }
        // Halt rows whose deadline/cancel bit as of now; then see if any
        // row still wants another step.
        let now = clock.now_ms();
        let mut any_live = false;
        for (r, c) in rows.iter().zip(cuts.iter_mut()) {
            if c.preempted || c.emitted >= r.target() {
                continue;
            }
            if r.halted(now) {
                c.preempted = true;
            } else {
                any_live = true;
            }
        }
        if !any_live {
            break;
        }
        clock.charge(CostEvent::DecodeStep { batch });
        steps += 1;
        for (r, c) in rows.iter().zip(cuts.iter_mut()) {
            if !c.preempted && c.emitted < r.target() {
                c.emitted += 1;
            }
        }
    }
    // A cap that bit below the natural length is a preemption too.
    for (r, c) in rows.iter().zip(cuts.iter_mut()) {
        if c.emitted < r.natural_len {
            c.preempted = true;
        }
    }
    (cuts, steps)
}

/// Cut one row that is *replayed from the cross-request cache*
/// ([`crate::engine::cache::EngineCache`]) instead of decoded: the same
/// cap / deadline / cancel semantics as [`run_decode_accounting`], but
/// **zero** decode steps are charged to the clock — the tokens already
/// exist, so serving them consumes no engine time. Because the clock
/// never advances, the row either is already halted at `now_ms` (spent
/// deadline or preset cancel → nothing emitted, like the engine's
/// dead-plan fast path) or emits instantly up to its cap. The emitted
/// count is exactly the decode steps a fresh call would have charged
/// for this row — the `decode_steps_saved` metric sums it.
pub fn cut_replayed_row(row: &RowBudget, now_ms: f64) -> RowCut {
    if row.halted(now_ms) {
        return RowCut {
            emitted: 0,
            preempted: true,
        };
    }
    let emitted = row.target();
    RowCut {
        emitted,
        preempted: emitted < row.natural_len,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, gen_vec, prop_assert};
    use crate::util::clock::{LatencyModel, SimClock};

    fn row(natural: usize) -> RowBudget {
        RowBudget {
            natural_len: natural,
            cap: usize::MAX,
            deadline_ms: f64::INFINITY,
            cancel: None,
            stop: None,
        }
    }

    fn step_ms(batch: usize) -> f64 {
        LatencyModel::default().cost_ms(CostEvent::DecodeStep { batch })
    }

    #[test]
    fn unbudgeted_rows_run_to_natural_length() {
        let clock = SimClock::new(LatencyModel::default());
        let rows = vec![row(5), row(9), row(0)];
        let (cuts, steps) = run_decode_accounting(&clock, 3, &rows, None);
        assert_eq!(steps, 9);
        assert_eq!(cuts[0], RowCut { emitted: 5, preempted: false });
        assert_eq!(cuts[1], RowCut { emitted: 9, preempted: false });
        assert_eq!(cuts[2], RowCut { emitted: 0, preempted: false });
        // the clock advanced exactly `steps` decode steps (the sim clock
        // truncates each charge to whole nanoseconds)
        assert!((clock.now_ms() - 9.0 * step_ms(3)).abs() < 1e-4);
    }

    #[test]
    fn token_cap_halts_a_row_mid_call() {
        let clock = SimClock::new(LatencyModel::default());
        let mut rows = vec![row(10), row(10)];
        rows[0].cap = 4;
        let (cuts, steps) = run_decode_accounting(&clock, 2, &rows, None);
        assert_eq!(cuts[0], RowCut { emitted: 4, preempted: true });
        assert_eq!(cuts[1], RowCut { emitted: 10, preempted: false });
        assert_eq!(steps, 10); // the uncapped row keeps the call alive
    }

    #[test]
    fn deadline_halts_mid_call_within_one_step() {
        let clock = SimClock::new(LatencyModel::default());
        // deadline after ~3.5 decode steps
        let deadline = 3.5 * step_ms(4);
        let mut rows = vec![row(50), row(50), row(50), row(50)];
        for r in rows.iter_mut() {
            r.deadline_ms = deadline;
        }
        let (cuts, steps) = run_decode_accounting(&clock, 4, &rows, None);
        assert_eq!(steps, 4); // halted right after the step that crossed it
        for c in &cuts {
            assert!(c.preempted);
            assert_eq!(c.emitted, 4);
        }
        // overshoot is bounded by one decode step
        assert!(clock.now_ms() <= deadline + step_ms(4) + 1e-9);
    }

    #[test]
    fn spent_deadline_emits_nothing() {
        let clock = SimClock::new(LatencyModel::default());
        clock.charge(CostEvent::DecodeStep { batch: 1 }); // clock > 0
        let mut rows = vec![row(10)];
        rows[0].deadline_ms = 0.0;
        let (cuts, steps) = run_decode_accounting(&clock, 1, &rows, None);
        assert_eq!(steps, 0);
        assert_eq!(cuts[0], RowCut { emitted: 0, preempted: true });
    }

    #[test]
    fn call_level_max_steps_bounds_charging() {
        let clock = SimClock::new(LatencyModel::default());
        let rows = vec![row(10), row(10)];
        let (cuts, steps) = run_decode_accounting(&clock, 2, &rows, Some(3));
        assert_eq!(steps, 3);
        for c in &cuts {
            assert_eq!(c.emitted, 3);
            assert!(c.preempted); // cut below natural length
        }
        assert!((clock.now_ms() - 3.0 * step_ms(2)).abs() < 1e-4);
    }

    #[test]
    fn preset_cancel_emits_nothing() {
        let clock = SimClock::new(LatencyModel::default());
        let flag = Arc::new(AtomicBool::new(true));
        let mut rows = vec![row(10), row(10)];
        rows[0].cancel = Some(flag);
        let (cuts, steps) = run_decode_accounting(&clock, 2, &rows, None);
        assert_eq!(cuts[0], RowCut { emitted: 0, preempted: true });
        assert_eq!(cuts[1], RowCut { emitted: 10, preempted: false });
        assert_eq!(steps, 10);
    }

    #[test]
    fn stop_flag_halts_like_cancel() {
        let clock = SimClock::new(LatencyModel::default());
        let mut rows = vec![row(10), row(10)];
        rows[0].stop = Some(Arc::new(AtomicBool::new(true)));
        let (cuts, steps) = run_decode_accounting(&clock, 2, &rows, None);
        assert_eq!(cuts[0], RowCut { emitted: 0, preempted: true });
        assert_eq!(cuts[1], RowCut { emitted: 10, preempted: false });
        assert_eq!(steps, 10);
    }

    #[test]
    fn per_row_deadlines_halt_independently() {
        let clock = SimClock::new(LatencyModel::default());
        let mut rows = vec![row(20), row(20)];
        rows[0].deadline_ms = 2.5 * step_ms(2);
        let (cuts, _) = run_decode_accounting(&clock, 2, &rows, None);
        assert!(cuts[0].preempted);
        assert_eq!(cuts[0].emitted, 3);
        assert_eq!(cuts[1], RowCut { emitted: 20, preempted: false });
    }

    #[test]
    fn replayed_rows_cut_like_decoded_rows_but_charge_nothing() {
        // uncut replay: full natural output, not preempted
        assert_eq!(
            cut_replayed_row(&row(7), 0.0),
            RowCut { emitted: 7, preempted: false }
        );
        // token cap bites below the natural length
        let mut capped = row(10);
        capped.cap = 4;
        assert_eq!(
            cut_replayed_row(&capped, 0.0),
            RowCut { emitted: 4, preempted: true }
        );
        // spent deadline / preset cancel: nothing emitted, like the
        // engine's dead-plan fast path
        let mut dead = row(10);
        dead.deadline_ms = 5.0;
        assert_eq!(
            cut_replayed_row(&dead, 5.0),
            RowCut { emitted: 0, preempted: true }
        );
        let mut cancelled = row(10);
        cancelled.cancel = Some(Arc::new(AtomicBool::new(true)));
        assert_eq!(
            cut_replayed_row(&cancelled, 0.0),
            RowCut { emitted: 0, preempted: true }
        );
        // a live deadline in the future never halts a replay (no time
        // passes while serving from cache)
        let mut live = row(3);
        live.deadline_ms = 5.0;
        assert_eq!(
            cut_replayed_row(&live, 4.999),
            RowCut { emitted: 3, preempted: false }
        );
    }

    #[test]
    fn prop_replayed_cut_matches_decode_accounting_when_time_is_free() {
        // With an infinite deadline budget the replay cut must agree
        // with what the charging loop would emit for the same row.
        forall(
            "replay cut == accounting cut (cap-only budgets)",
            100,
            |rng| {
                let natural = rng.below(40) as usize;
                let cap = if rng.below(2) == 0 {
                    rng.below(30) as usize
                } else {
                    usize::MAX
                };
                (natural, cap)
            },
            |&(natural, cap)| {
                let mut r = row(natural);
                r.cap = cap;
                let clock = SimClock::new(LatencyModel::default());
                let (cuts, _) =
                    run_decode_accounting(&clock, 1, std::slice::from_ref(&r), None);
                let replay = cut_replayed_row(&r, 0.0);
                prop_assert(
                    replay == cuts[0],
                    format!("replay {replay:?} != accounting {:?}", cuts[0]),
                )
            },
        );
    }

    #[test]
    fn prop_accounting_invariants() {
        forall(
            "preempt accounting invariants",
            150,
            |rng| {
                let rows = gen_vec(rng, 1..12, |r| {
                    let natural = r.below(40) as usize;
                    let cap = if r.below(3) == 0 {
                        r.below(30) as usize
                    } else {
                        usize::MAX
                    };
                    let deadline = if r.below(3) == 0 {
                        r.f64() * 200.0
                    } else {
                        f64::INFINITY
                    };
                    (natural, cap, deadline)
                });
                let batch = rows.len().max(1);
                (rows, batch)
            },
            |(specs, batch)| {
                let clock = SimClock::new(LatencyModel::default());
                let rows: Vec<RowBudget> = specs
                    .iter()
                    .map(|&(natural, cap, deadline)| RowBudget {
                        natural_len: natural,
                        cap,
                        deadline_ms: deadline,
                        cancel: None,
                        stop: None,
                    })
                    .collect();
                let (cuts, steps) = run_decode_accounting(&clock, *batch, &rows, None);
                let mut max_emitted = 0usize;
                for (r, c) in rows.iter().zip(&cuts) {
                    prop_assert(
                        c.emitted <= r.natural_len.min(r.cap),
                        format!("row emitted {} over bound", c.emitted),
                    )?;
                    prop_assert(
                        c.preempted == (c.emitted < r.natural_len),
                        format!(
                            "preempted flag inconsistent: emitted {} of {}",
                            c.emitted, r.natural_len
                        ),
                    )?;
                    max_emitted = max_emitted.max(c.emitted);
                }
                prop_assert(
                    steps == max_emitted,
                    format!("charged {steps} steps but max emitted is {max_emitted}"),
                )
            },
        );
    }
}
