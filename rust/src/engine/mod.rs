//! The inference engine: backend-driven engine threads (optionally a
//! sharded pool of them), plus the request protocol, coalescing
//! scheduler and continuous batcher in front of them.
//!
//! ## Execution backends
//!
//! What executes a bucket-shaped call is pluggable ([`backend`]): the
//! [`thread::DeviceBackend`] drives the AOT'd executables through PJRT,
//! while the [`backend::SimBackend`] emulates the trained models
//! deterministically with **no artifacts**, so every serve/stepper/bench
//! path can run engine-full on a fresh checkout. Scheduling, budget
//! preemption, metrics, and the generate/PRM/embed clock charges live in
//! the engine thread, identical for every backend; only the probe ops
//! charge their own [`crate::util::clock::CostEvent::Probe`] costs
//! inside the backend (their chunking is backend-internal — a new
//! backend must do the same or probe calls come out free on the sim
//! clock).
//!
//! ## Why one thread per engine
//!
//! The `xla` crate's PJRT handles are `Rc`-based (`!Send`), so exactly
//! one thread owns a device backend's client, compiled executables,
//! device-resident weight buffers and probe training state. Coordinator
//! threads talk to it over an mpsc channel — the same executor-thread
//! shape real GPU serving stacks use. On this 1-core testbed the engine
//! thread is also where all FLOPs are spent; batching exists to amortize
//! call overhead and to reproduce the paper's *latency structure* (one
//! batched call for N parallel candidates vs. D sequential rounds for
//! beam search).
//!
//! ## Scaling out: the engine pool
//!
//! [`pool::EnginePool`] owns N engines behind the same [`EngineHandle`]
//! client surface: submissions route through a deadline-aware placement
//! policy (least outstanding rows, EDF tiebreak — [`pool::place`]), each
//! engine keeps its own coalescing scheduler and metrics, and a pool of
//! one *is* the single-engine path, bit for bit.
//!
//! ## Generation granularity
//!
//! Generation is **in-graph** (`lm_generate` / `lm_chunk` artifacts):
//! prefill + sampling loop + KV cache live inside one executable call
//! (the crate returns outputs as a single tuple buffer, so per-token
//! round-trips would copy the whole cache through host literals). The
//! batcher therefore packs *sequence jobs* — candidate generations or
//! beam-chunk extensions — into bucket-sized calls. *Time*, however, is
//! charged one decode step at a time, and [`preempt`] halts individual
//! rows mid-call the moment their deadline/cancel/token budget runs out —
//! the engine-level enforcement half of the paper's latency story.
//!
//! ## Scheduling rounds and continuous batching
//!
//! Each engine's serve loop works in rounds ([`scheduler`]): every
//! message queued on its channel is drained (bounded by
//! [`scheduler::DRAIN_CAP`]) into per-op queues, so concurrent
//! `Generate`, `PrmScore` and `Embed` requests each merge into shared
//! bucket-shaped calls (bin-packed to minimize padding), and planned
//! generate calls dispatch earliest-deadline-first. On backends that
//! step natively ([`backend::Backend::stepping`]), generates go further:
//! the engine runs them **continuously** — a persistent slot table per
//! session, per-step retirement of finished/expired/cancelled rows, and
//! mid-decode admission of newly-arrived jobs into freed slots
//! ([`batcher::pick_slot_admission`]) — instead of waiting for the next
//! round. See `docs/engine.md` and `docs/backends.md` for the full
//! contracts.
//!
//! ## Cross-request cache tier
//!
//! [`cache::EngineCache`] (default-off, `CacheConfig`) sits in front of
//! every backend: a sharded prefix-trie replays temp-0 generations for
//! exact prompt hits without charging decode steps, and a sharded LRU
//! score cache subtracts already-scored PRM/embed rows from the batch
//! plan before bin-packing. Probe swaps invalidate everything. See
//! `docs/caching.md`.

pub mod backend;
pub mod batcher;
pub mod cache;
pub mod handle;
pub mod pool;
pub mod preempt;
pub mod protocol;
pub mod scheduler;
pub mod thread;

pub use backend::{
    Backend, BackendFactory, DecodeSession, EngineShapes, SimBackend, StepRows, StepTok,
};
pub use batcher::{
    job_len_bucket, pack_bins, pick_slot_admission, plan_batches, plan_batches_edf, BatchPlan,
};
pub use cache::EngineCache;
pub use handle::{Engine, EngineHandle, PendingReply};
pub use pool::{EngineLoad, EnginePool, PoolReporter};
pub use protocol::{EmbedKind, GenJob, GenKind, GenResult, ProbeTrainReport};
