//! The inference engine: a dedicated thread owning all PJRT state, plus
//! the request protocol and continuous batcher in front of it.
//!
//! ## Why a single engine thread
//!
//! The `xla` crate's PJRT handles are `Rc`-based (`!Send`), so exactly one
//! thread owns the client, the compiled executables, the device-resident
//! weight buffers and the probe training state. Coordinator threads talk
//! to it over an mpsc channel — the same executor-thread shape real GPU
//! serving stacks use. On this 1-core testbed the engine thread is also
//! where all FLOPs are spent; batching exists to amortize call overhead
//! and to reproduce the paper's *latency structure* (one batched call for
//! N parallel candidates vs. D sequential rounds for beam search).
//!
//! ## Generation granularity
//!
//! Generation is **in-graph** (`lm_generate` / `lm_chunk` artifacts):
//! prefill + sampling loop + KV cache live inside one executable call
//! (the crate returns outputs as a single tuple buffer, so per-token
//! round-trips would copy the whole cache through host literals). The
//! batcher therefore packs *sequence jobs* — candidate generations or
//! beam-chunk extensions — into bucket-sized calls. *Time*, however, is
//! charged one decode step at a time, and [`preempt`] halts individual
//! rows mid-call the moment their deadline/cancel/token budget runs out —
//! the engine-level enforcement half of the paper's latency story.
//!
//! ## Scheduling rounds
//!
//! The serve loop works in rounds ([`scheduler`]): every message queued
//! on the channel is drained into per-op queues, so concurrent
//! `Generate`, `PrmScore` and `Embed` requests each merge into shared
//! bucket-shaped calls (bin-packed to minimize padding), and planned
//! generate calls dispatch earliest-deadline-first. See
//! `docs/engine.md` for the full contract.

pub mod batcher;
pub mod handle;
pub mod preempt;
pub mod protocol;
pub mod scheduler;
pub mod thread;

pub use batcher::{pack_bins, plan_batches, plan_batches_edf, BatchPlan};
pub use handle::{Engine, EngineHandle, PendingReply};
pub use protocol::{EmbedKind, GenJob, GenKind, GenResult, ProbeTrainReport};
