//! Public engine API: spawn engine threads, talk to them synchronously.
//!
//! [`EngineHandle`] is the one client surface for both deployment
//! shapes: a *single* engine (one backend-driven thread, the historical
//! contract, bit-for-bit unchanged) or a *sharded pool*
//! ([`crate::engine::pool::EnginePool`]), where every submission routes
//! through a deadline-aware placement policy. Callers — strategies, the
//! stepper, the router — cannot tell the difference.

use crate::config::{BackendKind, Config};
use crate::engine::backend::{Backend, BackendFactory, EngineShapes, SimBackend};
use crate::engine::pool::{PoolGuard, PoolRouter};
use crate::engine::protocol::*;
use crate::engine::thread::{DeviceBackend, EngineThread};
use crate::error::{Error, Result};
use crate::log_info;
use crate::metrics::EngineMetrics;
use crate::util::clock::{self, SharedClock};
use crate::util::json::Value;
use std::cell::Cell;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// An in-flight engine reply: the submit half already put the request on
/// an engine channel (so it participates in that engine's next
/// coalescing round); the owner collects the result whenever it is
/// ready. This is the asynchronous seam the continuation executor
/// ([`crate::strategies::stepper`]) is built on — submit many requests'
/// work first, block on replies after, and the engine merges whatever
/// queued together. For pool-routed submissions the reply also carries
/// the placement accounting guard: the engine's outstanding-row count is
/// released when the reply is received (or the reply is dropped).
pub struct PendingReply<T> {
    rx: Receiver<Result<T>>,
    guard: Cell<Option<PoolGuard>>,
}

impl<T> std::fmt::Debug for PendingReply<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PendingReply").finish_non_exhaustive()
    }
}

impl<T> PendingReply<T> {
    fn new(rx: Receiver<Result<T>>, guard: Option<PoolGuard>) -> PendingReply<T> {
        PendingReply {
            rx,
            guard: Cell::new(guard),
        }
    }

    fn gone() -> Error {
        Error::Engine("engine thread dropped the reply".into())
    }

    /// Release the placement accounting (pool submissions only); called
    /// the moment a result is in hand.
    fn settle(&self) {
        self.guard.take();
    }

    /// Block until the reply arrives.
    pub fn wait(&self) -> Result<T> {
        let got = self.rx.recv().map_err(|_| Self::gone());
        self.settle();
        got?
    }

    /// Block up to `wait` (`None` = indefinitely). Returns `None` on
    /// timeout, leaving the reply collectable later.
    pub fn wait_timeout(&self, wait: Option<Duration>) -> Option<Result<T>> {
        match wait {
            None => Some(self.wait()),
            Some(d) => match self.rx.recv_timeout(d) {
                Ok(r) => {
                    self.settle();
                    Some(r)
                }
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => {
                    self.settle();
                    Some(Err(Self::gone()))
                }
            },
        }
    }

    /// Non-blocking poll: `None` while the engine is still working.
    pub fn try_wait(&self) -> Option<Result<T>> {
        match self.rx.try_recv() {
            Ok(r) => {
                self.settle();
                Some(r)
            }
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                self.settle();
                Some(Err(Self::gone()))
            }
        }
    }
}

/// Where a handle's messages go.
#[derive(Clone)]
enum Inner {
    /// Directly onto one engine thread's channel (the historical
    /// single-engine path — no placement, no accounting).
    Single(Sender<EngineMsg>),
    /// Through the pool's placement policy
    /// ([`crate::engine::pool::place`]).
    Pool(Arc<PoolRouter>),
}

/// Cheap, cloneable handle used by coordinator threads.
///
/// Calls are synchronous per handle, but each engine serves its channel
/// in coalescing rounds ([`crate::engine::scheduler`]): concurrent
/// `generate` / `prm_score` / `embed` calls from different clones merge
/// into shared bucket-shaped device calls, with generate plans
/// dispatched earliest-deadline-first. Request/result plumbing is
/// coalescing-invariant (each request gets exactly its own rows back),
/// and for deterministic ops — PRM scoring, embeds, greedy
/// (temperature-0) generation — the results equal serial execution;
/// sampled generation additionally depends on the per-call RNG key, so
/// its draws vary with batch composition just as they do between any
/// two serial calls.
///
/// Pool-backed handles additionally route every submission to one of N
/// engines (least outstanding rows, deadline-aware tiebreak — see
/// `docs/backends.md`); because temp-0 generation is a pure function of
/// the prompt on every backend, placement never changes results.
#[derive(Clone)]
pub struct EngineHandle {
    inner: Inner,
}

impl EngineHandle {
    pub(crate) fn single(tx: Sender<EngineMsg>) -> EngineHandle {
        EngineHandle {
            inner: Inner::Single(tx),
        }
    }

    pub(crate) fn pooled(router: Arc<PoolRouter>) -> EngineHandle {
        EngineHandle {
            inner: Inner::Pool(router),
        }
    }

    /// The pool's placement/utilization report, when this handle fronts
    /// an [`crate::engine::pool::EnginePool`] (`None` for single-engine
    /// handles — the serve report omits the pool section exactly as
    /// before).
    pub fn pool_report(&self) -> Option<Value> {
        match &self.inner {
            Inner::Single(_) => None,
            Inner::Pool(router) => Some(router.report()),
        }
    }

    /// Route one message: direct send for single engines, placed send
    /// (with row/deadline accounting) for pools.
    fn route(
        &self,
        msg: EngineMsg,
        rows: usize,
        deadline_ms: f64,
        op: &'static str,
    ) -> Result<Option<PoolGuard>> {
        match &self.inner {
            Inner::Single(tx) => {
                tx.send(msg)
                    .map_err(|_| Error::Engine("engine thread is gone".into()))?;
                Ok(None)
            }
            Inner::Pool(router) => Ok(Some(router.submit(msg, rows, deadline_ms, op)?)),
        }
    }

    /// Generate all jobs (blocking); results in job order.
    pub fn generate(&self, jobs: Vec<GenJob>) -> Result<Vec<GenResult>> {
        self.generate_with_deadline(jobs, None)
    }

    /// Generate under an *absolute* engine-clock deadline: once
    /// `deadline_ms` passes, the engine halts the in-flight batched call
    /// for these jobs and returns partial results tagged
    /// [`GenResult::preempted`]. Per-job caps/cancel ride on [`GenJob`].
    pub fn generate_with_deadline(
        &self,
        jobs: Vec<GenJob>,
        deadline_ms: Option<f64>,
    ) -> Result<Vec<GenResult>> {
        self.submit_generate(jobs, deadline_ms)?.wait()
    }

    /// Queue a generate call without blocking on the reply. All requests
    /// submitted before anyone blocks land on their engine's channel
    /// together, so its scheduler drains them into one coalescing round.
    pub fn submit_generate(
        &self,
        jobs: Vec<GenJob>,
        deadline_ms: Option<f64>,
    ) -> Result<PendingReply<Vec<GenResult>>> {
        let rows = jobs.len();
        let (reply, rx) = channel();
        let guard = self.route(
            EngineMsg::Generate {
                jobs,
                deadline_ms,
                reply,
            },
            rows,
            deadline_ms.unwrap_or(f64::INFINITY),
            "generate",
        )?;
        Ok(PendingReply::new(rx, guard))
    }

    /// Score CoT prefixes with the PRM.
    pub fn prm_score(&self, prefixes: Vec<Vec<u32>>) -> Result<Vec<f32>> {
        self.submit_prm_score(prefixes)?.wait()
    }

    /// Queue a PRM scoring call without blocking on the reply.
    pub fn submit_prm_score(
        &self,
        prefixes: Vec<Vec<u32>>,
    ) -> Result<PendingReply<Vec<f32>>> {
        let rows = prefixes.len();
        let (reply, rx) = channel();
        let guard = self.route(
            EngineMsg::PrmScore { prefixes, reply },
            rows,
            f64::INFINITY,
            "prm_score",
        )?;
        Ok(PendingReply::new(rx, guard))
    }

    /// Embed queries.
    pub fn embed(&self, kind: EmbedKind, queries: Vec<Vec<u32>>) -> Result<Vec<Vec<f32>>> {
        let rows = queries.len();
        let (reply, rx) = channel();
        let guard = self.route(
            EngineMsg::Embed {
                kind,
                queries,
                reply,
            },
            rows,
            f64::INFINITY,
            "embed",
        )?;
        PendingReply::new(rx, guard).wait()
    }

    /// Probe forward (logits) with the engine's current probe params.
    pub fn probe_fwd(&self, feats: Vec<Vec<f32>>) -> Result<Vec<f32>> {
        let rows = feats.len();
        let (reply, rx) = channel();
        let guard = self.route(
            EngineMsg::ProbeFwd { feats, reply },
            rows,
            f64::INFINITY,
            "probe_fwd",
        )?;
        PendingReply::new(rx, guard).wait()
    }

    /// Train the probe; the engine keeps (and returns) the best params.
    /// On a pool, training runs on engine #0 and the winning parameters
    /// are then installed on every other engine, so replicas stay
    /// interchangeable.
    #[allow(clippy::too_many_arguments)]
    pub fn probe_train(
        &self,
        train_feats: Vec<Vec<f32>>,
        train_labels: Vec<f32>,
        val_feats: Vec<Vec<f32>>,
        val_labels: Vec<f32>,
        epochs: usize,
        patience: usize,
    ) -> Result<ProbeTrainReport> {
        let (reply, rx) = channel();
        let msg = EngineMsg::ProbeTrain {
            train_feats,
            train_labels,
            val_feats,
            val_labels,
            epochs,
            patience,
            reply,
        };
        match &self.inner {
            Inner::Single(tx) => {
                tx.send(msg)
                    .map_err(|_| Error::Engine("engine thread is gone".into()))?;
                PendingReply::new(rx, None).wait()
            }
            Inner::Pool(router) => {
                router.send_to(0, msg, "probe_train")?;
                let report = PendingReply::new(rx, None).wait()?;
                router.broadcast_probe_load(report.params.clone(), 1)?;
                Ok(report)
            }
        }
    }

    /// Replace probe parameters (e.g. from a saved checkpoint). On a
    /// pool the parameters are installed on *every* engine.
    pub fn probe_load(&self, params: Vec<f32>) -> Result<()> {
        match &self.inner {
            Inner::Single(tx) => {
                let (reply, rx) = channel();
                tx.send(EngineMsg::ProbeLoad { params, reply })
                    .map_err(|_| Error::Engine("engine thread is gone".into()))?;
                PendingReply::new(rx, None).wait()
            }
            Inner::Pool(router) => router.broadcast_probe_load(params, 0),
        }
    }

    /// Engine diagnostics as JSON. For a pool: engine #0's diagnostics
    /// plus a `pool` section with placement and per-engine utilization.
    pub fn info(&self) -> Result<Value> {
        let (reply, rx) = channel();
        let msg = EngineMsg::Info { reply };
        match &self.inner {
            Inner::Single(tx) => {
                tx.send(msg)
                    .map_err(|_| Error::Engine("engine thread is gone".into()))?;
                PendingReply::new(rx, None).wait()
            }
            Inner::Pool(router) => {
                router.send_to(0, msg, "info")?;
                let mut v = PendingReply::new(rx, None).wait()?;
                v.set("pool", router.report());
                Ok(v)
            }
        }
    }
}

/// Owns one engine thread; shuts it down on drop.
pub struct Engine {
    handle: EngineHandle,
    shutdown: Sender<EngineMsg>,
    join: Option<JoinHandle<()>>,
    pub metrics: Arc<EngineMetrics>,
    pub clock: SharedClock,
}

impl Engine {
    /// Spawn one engine thread (backend per `cfg.engine.backend`) and
    /// wait until the backend is ready.
    pub fn start(cfg: &Config) -> Result<Engine> {
        let clock: SharedClock = if cfg.engine.sim_clock {
            clock::sim_clock()
        } else {
            clock::real_clock()
        };
        Self::start_with_clock(cfg, clock)
    }

    pub fn start_with_clock(cfg: &Config, clock: SharedClock) -> Result<Engine> {
        Self::start_member(cfg, clock, 0)
    }

    /// Spawn pool member `index`: same artifacts/config, its own RNG
    /// stream (member 0 reproduces the historical single-engine stream
    /// exactly) and its own thread, sharing `clock` with its siblings so
    /// deadlines mean the same thing on every engine.
    pub(crate) fn start_member(cfg: &Config, clock: SharedClock, index: usize) -> Result<Engine> {
        let metrics = Arc::new(EngineMetrics::new());
        let (tx, rx) = channel();
        let (ready_tx, ready_rx) = channel();
        let factory = Self::backend_factory(cfg, clock.clone(), index);
        let thread_clock = clock.clone();
        let thread_metrics = metrics.clone();
        let join = std::thread::Builder::new()
            .name(format!("ttc-engine-{index}"))
            .spawn(move || match factory() {
                Ok(backend) => {
                    let _ = ready_tx.send(Ok(()));
                    EngineThread::new(backend, thread_clock, thread_metrics).serve(rx);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            })
            .map_err(|e| Error::Engine(format!("cannot spawn engine thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Engine("engine thread died during startup".into()))??;
        match cfg.engine.backend {
            BackendKind::Device => log_info!(
                "engine #{index} started (device backend, artifacts: {})",
                cfg.paths.artifacts.display()
            ),
            BackendKind::Sim => log_info!("engine #{index} started (sim backend, no artifacts)"),
        }
        Ok(Engine {
            handle: EngineHandle::single(tx.clone()),
            shutdown: tx,
            join: Some(join),
            metrics,
            clock,
        })
    }

    /// The backend constructor that runs on the engine thread: PJRT
    /// state is `!Send`, so only this `Send` closure crosses the spawn.
    fn backend_factory(cfg: &Config, clock: SharedClock, index: usize) -> BackendFactory {
        let kind = cfg.engine.backend;
        let artifacts = cfg.paths.artifacts.clone();
        let seed = cfg.seed;
        let sim_shapes = EngineShapes::sim_default(&cfg.engine);
        Box::new(move || -> Result<Box<dyn Backend>> {
            match kind {
                BackendKind::Device => Ok(Box::new(DeviceBackend::new(
                    &artifacts,
                    clock,
                    seed,
                    index as u64,
                )?)),
                BackendKind::Sim => Ok(Box::new(SimBackend::new(
                    sim_shapes,
                    clock,
                    seed,
                    index as u64,
                ))),
            }
        })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    /// This engine's raw submission channel — pool plumbing only.
    pub(crate) fn sender(&self) -> Sender<EngineMsg> {
        self.shutdown.clone()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.shutdown.send(EngineMsg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
