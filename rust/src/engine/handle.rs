//! Public engine API: spawn engine threads, talk to them synchronously.
//!
//! [`EngineHandle`] is the one client surface for both deployment
//! shapes: a *single* engine (one backend-driven thread, the historical
//! contract, bit-for-bit unchanged) or a *sharded pool*
//! ([`crate::engine::pool::EnginePool`]), where every submission routes
//! through a deadline-aware placement policy. Callers — strategies, the
//! stepper, the router — cannot tell the difference, including under
//! partial failure: pool-routed submissions carry a resubmittable copy
//! of the request, so an engine that dies (or whose remote shard stops
//! answering) mid-flight gets its work re-placed on a live engine
//! instead of failing the caller.

use crate::config::{BackendKind, Config};
use crate::engine::backend::{Backend, BackendFactory, EngineShapes, SimBackend};
use crate::engine::cache::EngineCache;
use crate::engine::pool::{MsgFactory, PoolGuard, PoolRouter};
use crate::engine::protocol::*;
use crate::engine::thread::{DeviceBackend, EngineThread};
use crate::error::{Error, Result};
use crate::log_info;
use crate::metrics::EngineMetrics;
use crate::util::clock::{self, SharedClock};
use crate::util::json::Value;
use std::cell::{Cell, RefCell};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Everything needed to re-place a pool submission on another engine:
/// the message factory (rebuilds the request against a fresh reply
/// channel), its accounting footprint, and a resubmission budget so a
/// systemic fault cannot ping-pong forever.
struct RetryState<T> {
    router: Arc<PoolRouter>,
    make_msg: MsgFactory<T>,
    rows: usize,
    deadline_ms: f64,
    op: &'static str,
    attempts_left: Cell<usize>,
}

/// An in-flight engine reply: the submit half already put the request on
/// an engine channel (so it participates in that engine's next
/// coalescing round); the owner collects the result whenever it is
/// ready. This is the asynchronous seam the continuation executor
/// ([`crate::strategies::stepper`]) is built on — submit many requests'
/// work first, block on replies after, and the engine merges whatever
/// queued together. For pool-routed submissions the reply also carries
/// the placement accounting guard (released when the result is
/// harvested or the reply dropped) and the failover state: a reply that
/// dies with a *transient* net fault, or whose engine thread drops the
/// channel, marks that engine dead and transparently resubmits on a
/// live one.
pub struct PendingReply<T> {
    rx: RefCell<Receiver<Result<T>>>,
    guard: Cell<Option<PoolGuard>>,
    retry: Option<RetryState<T>>,
}

impl<T> std::fmt::Debug for PendingReply<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PendingReply").finish_non_exhaustive()
    }
}

impl<T> PendingReply<T> {
    fn new(rx: Receiver<Result<T>>, guard: Option<PoolGuard>) -> PendingReply<T> {
        PendingReply {
            rx: RefCell::new(rx),
            guard: Cell::new(guard),
            retry: None,
        }
    }

    fn with_retry(
        rx: Receiver<Result<T>>,
        guard: PoolGuard,
        retry: RetryState<T>,
    ) -> PendingReply<T> {
        PendingReply {
            rx: RefCell::new(rx),
            guard: Cell::new(Some(guard)),
            retry: Some(retry),
        }
    }

    fn gone() -> Error {
        Error::Engine("engine thread dropped the reply".into())
    }

    /// Release the placement accounting (pool submissions only); called
    /// the moment a result is in hand.
    fn settle(&self) {
        self.guard.take();
    }

    /// Attempt to rescue this reply after `cause` (a transient fault or
    /// a dropped reply channel): mark the engine dead and resubmit on a
    /// live one. Returns `None` when the resubmission is in flight
    /// (keep waiting), or `Some(err)` when the fault is terminal.
    fn failover(&self, cause: Error) -> Option<Error> {
        let Some(retry) = &self.retry else {
            self.settle();
            return Some(cause);
        };
        if let Some(guard) = self.guard.take() {
            retry
                .router
                .mark_dead(guard.engine(), retry.op, &cause.to_string());
        }
        if retry.attempts_left.get() == 0 {
            return Some(cause);
        }
        retry.attempts_left.set(retry.attempts_left.get() - 1);
        match retry
            .router
            .submit_with(&retry.make_msg, retry.rows, retry.deadline_ms, retry.op)
        {
            Ok((rx, guard)) => {
                retry.router.metrics.rerouted_submits.inc();
                *self.rx.borrow_mut() = rx;
                self.guard.set(Some(guard));
                None
            }
            Err(e) => Some(e),
        }
    }

    /// Dispatch one received value: `Ok(result)` settles, a transient
    /// net error or dropped channel triggers failover, anything else is
    /// the engine's final answer.
    fn on_reply(&self, got: std::result::Result<Result<T>, Error>) -> Option<Result<T>> {
        match got {
            Ok(Ok(v)) => {
                self.settle();
                Some(Ok(v))
            }
            Ok(Err(e)) if e.is_transient_net() => self.failover(e).map(Err),
            Ok(Err(e)) => {
                self.settle();
                Some(Err(e))
            }
            Err(cause) => self.failover(cause).map(Err),
        }
    }

    /// Block until the reply arrives.
    pub fn wait(&self) -> Result<T> {
        loop {
            let got = { self.rx.borrow().recv() }.map_err(|_| Self::gone());
            if let Some(done) = self.on_reply(got) {
                return done;
            }
        }
    }

    /// Block up to `wait` (`None` = indefinitely). Returns `None` on
    /// timeout, leaving the reply collectable later.
    pub fn wait_timeout(&self, wait: Option<Duration>) -> Option<Result<T>> {
        let Some(d) = wait else {
            return Some(self.wait());
        };
        let deadline = Instant::now() + d;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            let got = match { self.rx.borrow().recv_timeout(remaining) } {
                Ok(r) => Ok(r),
                Err(RecvTimeoutError::Timeout) => return None,
                Err(RecvTimeoutError::Disconnected) => Err(Self::gone()),
            };
            if let Some(done) = self.on_reply(got) {
                return Some(done);
            }
        }
    }

    /// Non-blocking poll: `None` while the engine is still working (or
    /// a failover resubmission is in flight).
    pub fn try_wait(&self) -> Option<Result<T>> {
        let got = match { self.rx.borrow().try_recv() } {
            Ok(r) => Ok(r),
            Err(TryRecvError::Empty) => return None,
            Err(TryRecvError::Disconnected) => Err(Self::gone()),
        };
        // `None` from on_reply means a failover resubmission is in
        // flight — the fresh engine hasn't answered yet, so report
        // "still working".
        self.on_reply(got)
    }
}

/// Where a handle's messages go.
#[derive(Clone)]
enum Inner {
    /// Directly onto one engine thread's channel (the historical
    /// single-engine path — no placement, no accounting).
    Single(Sender<EngineMsg>),
    /// Through the pool's placement policy
    /// ([`crate::engine::pool::place_live`]).
    Pool(Arc<PoolRouter>),
}

/// Cheap, cloneable handle used by coordinator threads.
///
/// Calls are synchronous per handle, but each engine serves its channel
/// in coalescing rounds ([`crate::engine::scheduler`]): concurrent
/// `generate` / `prm_score` / `embed` calls from different clones merge
/// into shared bucket-shaped device calls, with generate plans
/// dispatched earliest-deadline-first. Request/result plumbing is
/// coalescing-invariant (each request gets exactly its own rows back),
/// and for deterministic ops — PRM scoring, embeds, greedy
/// (temperature-0) generation — the results equal serial execution;
/// sampled generation additionally depends on the per-call RNG key, so
/// its draws vary with batch composition just as they do between any
/// two serial calls.
///
/// Pool-backed handles additionally route every submission to one of N
/// engines (least outstanding rows, deadline-aware tiebreak, dead
/// engines excluded — see `docs/backends.md`); because temp-0
/// generation is a pure function of the prompt on every backend,
/// placement and failover never change results.
#[derive(Clone)]
pub struct EngineHandle {
    inner: Inner,
}

impl EngineHandle {
    pub(crate) fn single(tx: Sender<EngineMsg>) -> EngineHandle {
        EngineHandle {
            inner: Inner::Single(tx),
        }
    }

    pub(crate) fn pooled(router: Arc<PoolRouter>) -> EngineHandle {
        EngineHandle {
            inner: Inner::Pool(router),
        }
    }

    /// The pool's placement/utilization report, when this handle fronts
    /// an [`crate::engine::pool::EnginePool`] (`None` for single-engine
    /// handles — the serve report omits the pool section exactly as
    /// before).
    pub fn pool_report(&self) -> Option<Value> {
        match &self.inner {
            Inner::Single(_) => None,
            Inner::Pool(router) => Some(router.report()),
        }
    }

    /// Submit one data-plane request. Single engines get the message
    /// directly (no placement, no accounting, no failover — the
    /// historical contract). Pools get a rebuildable message factory so
    /// the submission can hop engines: at submit time when a channel is
    /// closed, and in flight via [`PendingReply`] when the reply dies.
    fn submit<T: 'static>(
        &self,
        make_msg: MsgFactory<T>,
        rows: usize,
        deadline_ms: f64,
        op: &'static str,
    ) -> Result<PendingReply<T>> {
        match &self.inner {
            Inner::Single(tx) => {
                let (reply, rx) = channel();
                tx.send(make_msg(reply))
                    .map_err(|_| Error::Engine("engine thread is gone".into()))?;
                Ok(PendingReply::new(rx, None))
            }
            Inner::Pool(router) => {
                let (rx, guard) = router.submit_with(&make_msg, rows, deadline_ms, op)?;
                let retry = RetryState {
                    router: router.clone(),
                    make_msg,
                    rows,
                    deadline_ms,
                    op,
                    // At most one hop per engine: a fault that survives
                    // N re-placements is systemic, not a dead shard.
                    attempts_left: Cell::new(router.engines()),
                };
                Ok(PendingReply::with_retry(rx, guard, retry))
            }
        }
    }

    /// Generate all jobs (blocking); results in job order.
    pub fn generate(&self, jobs: Vec<GenJob>) -> Result<Vec<GenResult>> {
        self.generate_with_deadline(jobs, None)
    }

    /// Generate under an *absolute* engine-clock deadline: once
    /// `deadline_ms` passes, the engine halts the in-flight batched call
    /// for these jobs and returns partial results tagged
    /// [`GenResult::preempted`]. Per-job caps/cancel ride on [`GenJob`].
    pub fn generate_with_deadline(
        &self,
        jobs: Vec<GenJob>,
        deadline_ms: Option<f64>,
    ) -> Result<Vec<GenResult>> {
        self.submit_generate(jobs, deadline_ms)?.wait()
    }

    /// Queue a generate call without blocking on the reply. All requests
    /// submitted before anyone blocks land on their engine's channel
    /// together, so its scheduler drains them into one coalescing round.
    pub fn submit_generate(
        &self,
        jobs: Vec<GenJob>,
        deadline_ms: Option<f64>,
    ) -> Result<PendingReply<Vec<GenResult>>> {
        let rows = jobs.len();
        self.submit(
            Box::new(move |reply| EngineMsg::Generate {
                jobs: jobs.clone(),
                deadline_ms,
                reply,
            }),
            rows,
            deadline_ms.unwrap_or(f64::INFINITY),
            "generate",
        )
    }

    /// Score CoT prefixes with the PRM.
    pub fn prm_score(&self, prefixes: Vec<Vec<u32>>) -> Result<Vec<f32>> {
        self.submit_prm_score(prefixes)?.wait()
    }

    /// Queue a PRM scoring call without blocking on the reply.
    pub fn submit_prm_score(&self, prefixes: Vec<Vec<u32>>) -> Result<PendingReply<Vec<f32>>> {
        let rows = prefixes.len();
        self.submit(
            Box::new(move |reply| EngineMsg::PrmScore {
                prefixes: prefixes.clone(),
                reply,
            }),
            rows,
            f64::INFINITY,
            "prm_score",
        )
    }

    /// Embed queries.
    pub fn embed(&self, kind: EmbedKind, queries: Vec<Vec<u32>>) -> Result<Vec<Vec<f32>>> {
        let rows = queries.len();
        self.submit(
            Box::new(move |reply| EngineMsg::Embed {
                kind,
                queries: queries.clone(),
                reply,
            }),
            rows,
            f64::INFINITY,
            "embed",
        )?
        .wait()
    }

    /// Probe forward (logits) with the engine's current probe params.
    pub fn probe_fwd(&self, feats: Vec<Vec<f32>>) -> Result<Vec<f32>> {
        let rows = feats.len();
        self.submit(
            Box::new(move |reply| EngineMsg::ProbeFwd {
                feats: feats.clone(),
                reply,
            }),
            rows,
            f64::INFINITY,
            "probe_fwd",
        )?
        .wait()
    }

    /// Train the probe; the engine keeps (and returns) the best params.
    /// On a pool, training runs on the lowest-index live engine and the
    /// winning parameters are then installed on every other live engine,
    /// so replicas stay interchangeable.
    #[allow(clippy::too_many_arguments)]
    pub fn probe_train(
        &self,
        train_feats: Vec<Vec<f32>>,
        train_labels: Vec<f32>,
        val_feats: Vec<Vec<f32>>,
        val_labels: Vec<f32>,
        epochs: usize,
        patience: usize,
    ) -> Result<ProbeTrainReport> {
        let make = |reply| EngineMsg::ProbeTrain {
            train_feats: train_feats.clone(),
            train_labels: train_labels.clone(),
            val_feats: val_feats.clone(),
            val_labels: val_labels.clone(),
            epochs,
            patience,
            reply,
        };
        match &self.inner {
            Inner::Single(tx) => {
                let (reply, rx) = channel();
                tx.send(make(reply))
                    .map_err(|_| Error::Engine("engine thread is gone".into()))?;
                PendingReply::new(rx, None).wait()
            }
            Inner::Pool(router) => {
                // Trainer election + dead-engine retry: a trainer that
                // dies before answering just means the next live engine
                // trains instead (training is deterministic per params).
                loop {
                    let trainer = router.first_live("probe_train")?;
                    let (reply, rx) = channel();
                    if router.send_to(trainer, make(reply), "probe_train").is_err() {
                        continue; // marked dead; elect the next one
                    }
                    match PendingReply::new(rx, None).wait() {
                        Ok(report) => {
                            router.broadcast_probe_load(report.params.clone(), Some(trainer))?;
                            return Ok(report);
                        }
                        Err(e) if e.is_transient_net() => {
                            router.mark_dead(trainer, "probe_train", &e.to_string());
                        }
                        Err(e)
                            if e.to_string()
                                .contains("engine thread dropped the reply") =>
                        {
                            router.mark_dead(trainer, "probe_train", &e.to_string());
                        }
                        Err(e) => return Err(e),
                    }
                }
            }
        }
    }

    /// Replace probe parameters (e.g. from a saved checkpoint). On a
    /// pool the parameters are installed on *every* live engine.
    pub fn probe_load(&self, params: Vec<f32>) -> Result<()> {
        match &self.inner {
            Inner::Single(tx) => {
                let (reply, rx) = channel();
                tx.send(EngineMsg::ProbeLoad { params, reply })
                    .map_err(|_| Error::Engine("engine thread is gone".into()))?;
                PendingReply::new(rx, None).wait()
            }
            Inner::Pool(router) => router.broadcast_probe_load(params, None),
        }
    }

    /// Engine diagnostics as JSON. For a pool: the lowest-index live
    /// engine's diagnostics plus a `pool` section with placement,
    /// health and per-engine utilization.
    pub fn info(&self) -> Result<Value> {
        match &self.inner {
            Inner::Single(tx) => {
                let (reply, rx) = channel();
                tx.send(EngineMsg::Info { reply })
                    .map_err(|_| Error::Engine("engine thread is gone".into()))?;
                PendingReply::new(rx, None).wait()
            }
            Inner::Pool(router) => loop {
                let idx = router.first_live("info")?;
                let (reply, rx) = channel();
                if router.send_to(idx, EngineMsg::Info { reply }, "info").is_err() {
                    continue;
                }
                match PendingReply::new(rx, None).wait() {
                    Ok(mut v) => {
                        v.set("pool", router.report());
                        return Ok(v);
                    }
                    Err(e)
                        if e.is_transient_net()
                            || e.to_string().contains("engine thread dropped the reply") =>
                    {
                        router.mark_dead(idx, "info", &e.to_string());
                    }
                    Err(e) => return Err(e),
                }
            },
        }
    }
}

/// Owns one engine thread; shuts it down on drop.
pub struct Engine {
    handle: EngineHandle,
    shutdown: Sender<EngineMsg>,
    join: Option<JoinHandle<()>>,
    pub metrics: Arc<EngineMetrics>,
    pub clock: SharedClock,
}

impl Engine {
    /// Spawn one engine thread (backend per `cfg.engine.backend`) and
    /// wait until the backend is ready.
    pub fn start(cfg: &Config) -> Result<Engine> {
        let clock: SharedClock = if cfg.engine.sim_clock {
            clock::sim_clock()
        } else {
            clock::real_clock()
        };
        Self::start_with_clock(cfg, clock)
    }

    pub fn start_with_clock(cfg: &Config, clock: SharedClock) -> Result<Engine> {
        Self::start_member(cfg, clock, 0, EngineCache::from_config(&cfg.engine.cache))
    }

    /// Spawn pool member `index`: same artifacts/config, its own RNG
    /// stream (member 0 reproduces the historical single-engine stream
    /// exactly) and its own thread, sharing `clock` with its siblings so
    /// deadlines mean the same thing on every engine. `cache` is the
    /// pool-shared cross-request cache tier (`None` when disabled).
    pub(crate) fn start_member(
        cfg: &Config,
        clock: SharedClock,
        index: usize,
        cache: Option<Arc<EngineCache>>,
    ) -> Result<Engine> {
        let factory = Self::backend_factory(cfg, clock.clone(), index);
        let label = match cfg.engine.backend {
            BackendKind::Device => "device backend",
            BackendKind::Sim => "sim backend",
            BackendKind::Remote => "remote backend",
        };
        Self::start_member_with_factory(clock, index, factory, label, cache, cfg.engine.continuous)
    }

    /// Spawn pool member `index` around a caller-supplied backend
    /// factory (the closure runs *on* the engine thread — PJRT state
    /// and live connections are `!Send`, so only this `Send` closure
    /// crosses the spawn).
    pub(crate) fn start_member_with_factory(
        clock: SharedClock,
        index: usize,
        factory: BackendFactory,
        label: &str,
        cache: Option<Arc<EngineCache>>,
        continuous: bool,
    ) -> Result<Engine> {
        let metrics = Arc::new(EngineMetrics::new());
        let (tx, rx) = channel();
        let (ready_tx, ready_rx) = channel();
        let thread_clock = clock.clone();
        let thread_metrics = metrics.clone();
        let join = std::thread::Builder::new()
            .name(format!("ttc-engine-{index}"))
            .spawn(move || match factory() {
                Ok(backend) => {
                    let _ = ready_tx.send(Ok(()));
                    EngineThread::new(backend, thread_clock, thread_metrics)
                        .with_cache(cache)
                        .with_continuous(continuous)
                        .serve(rx);
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                }
            })
            .map_err(|e| Error::Engine(format!("cannot spawn engine thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Engine("engine thread died during startup".into()))??;
        log_info!("engine #{index} started ({label})");
        Ok(Engine {
            handle: EngineHandle::single(tx.clone()),
            shutdown: tx,
            join: Some(join),
            metrics,
            clock,
        })
    }

    /// The backend constructor that runs on the engine thread: PJRT
    /// state is `!Send`, so only this `Send` closure crosses the spawn.
    fn backend_factory(cfg: &Config, clock: SharedClock, index: usize) -> BackendFactory {
        let kind = cfg.engine.backend;
        let artifacts = cfg.paths.artifacts.clone();
        let seed = cfg.seed;
        let sim_shapes = EngineShapes::sim_default(&cfg.engine);
        let remote_addrs = cfg.engine.remote_addrs.clone();
        let remote_cfg = crate::net::RemoteConfig {
            call_timeout_ms: cfg.engine.remote_timeout_ms,
            retries: cfg.engine.remote_retries,
            wire_codec: cfg.engine.wire_codec,
            ..crate::net::RemoteConfig::default()
        };
        Box::new(move || -> Result<Box<dyn Backend>> {
            match kind {
                BackendKind::Device => Ok(Box::new(DeviceBackend::new(
                    &artifacts,
                    clock,
                    seed,
                    index as u64,
                )?)),
                BackendKind::Sim => Ok(Box::new(SimBackend::new(
                    sim_shapes,
                    clock,
                    seed,
                    index as u64,
                ))),
                BackendKind::Remote => {
                    if remote_addrs.is_empty() {
                        return Err(Error::Config(
                            "backend 'remote' needs at least one address \
                             (engine.remote_addrs / --remote host:port[,host:port...])"
                                .into(),
                        ));
                    }
                    let addr = remote_addrs[index % remote_addrs.len()].clone();
                    let connector = crate::net::TcpConnector::new(
                        addr,
                        Duration::from_secs_f64(
                            (remote_cfg.connect_timeout_ms / 1e3).max(1e-3),
                        ),
                    );
                    Ok(Box::new(crate::net::RemoteBackend::connect(
                        Box::new(connector),
                        remote_cfg,
                        clock,
                        crate::net::NetMetrics::new(),
                    )?))
                }
            }
        })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    /// This engine's raw submission channel — pool plumbing only.
    pub(crate) fn sender(&self) -> Sender<EngineMsg> {
        self.shutdown.clone()
    }

    /// Shut the engine thread down immediately (fault injection /
    /// explicit teardown); drop does the same thing implicitly.
    pub(crate) fn shutdown_now(&mut self) {
        let _ = self.shutdown.send(EngineMsg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shutdown_now();
    }
}
