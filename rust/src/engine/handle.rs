//! Public engine API: spawn the engine thread, talk to it synchronously.

use crate::config::Config;
use crate::engine::protocol::*;
use crate::engine::thread::EngineThread;
use crate::error::{Error, Result};
use crate::metrics::EngineMetrics;
use crate::util::clock::{self, SharedClock};
use crate::util::json::Value;
use crate::log_info;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// An in-flight engine reply: the submit half already put the request on
/// the engine channel (so it participates in the scheduler's next
/// coalescing round); the owner collects the result whenever it is
/// ready. This is the asynchronous seam the continuation executor
/// ([`crate::strategies::stepper`]) is built on — submit many requests'
/// work first, block on replies after, and the engine merges whatever
/// queued together.
#[derive(Debug)]
pub struct PendingReply<T> {
    rx: Receiver<Result<T>>,
}

impl<T> PendingReply<T> {
    fn gone() -> Error {
        Error::Engine("engine thread dropped the reply".into())
    }

    /// Block until the reply arrives.
    pub fn wait(&self) -> Result<T> {
        self.rx.recv().map_err(|_| Self::gone())?
    }

    /// Block up to `wait` (`None` = indefinitely). Returns `None` on
    /// timeout, leaving the reply collectable later.
    pub fn wait_timeout(&self, wait: Option<Duration>) -> Option<Result<T>> {
        match wait {
            None => Some(self.wait()),
            Some(d) => match self.rx.recv_timeout(d) {
                Ok(r) => Some(r),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => Some(Err(Self::gone())),
            },
        }
    }

    /// Non-blocking poll: `None` while the engine is still working.
    pub fn try_wait(&self) -> Option<Result<T>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => Some(Err(Self::gone())),
        }
    }
}

/// Cheap, cloneable handle used by coordinator threads.
///
/// Calls are synchronous per handle, but the engine serves the channel
/// in coalescing rounds ([`crate::engine::scheduler`]): concurrent
/// `generate` / `prm_score` / `embed` calls from different clones merge
/// into shared bucket-shaped device calls, with generate plans
/// dispatched earliest-deadline-first. Request/result plumbing is
/// coalescing-invariant (each request gets exactly its own rows back),
/// and for deterministic ops — PRM scoring, embeds, greedy
/// (temperature-0) generation — the results equal serial execution;
/// sampled generation additionally depends on the per-call RNG key, so
/// its draws vary with batch composition just as they do between any
/// two serial calls.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Sender<EngineMsg>,
}

macro_rules! rpc {
    ($self:ident, $variant:ident { $($field:ident : $value:expr),* $(,)? }) => {{
        let (reply, rx) = channel();
        $self
            .tx
            .send(EngineMsg::$variant { $($field: $value,)* reply })
            .map_err(|_| Error::Engine("engine thread is gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Engine("engine thread dropped the reply".into()))?
    }};
}

impl EngineHandle {
    /// Generate all jobs (blocking); results in job order.
    pub fn generate(&self, jobs: Vec<GenJob>) -> Result<Vec<GenResult>> {
        self.generate_with_deadline(jobs, None)
    }

    /// Generate under an *absolute* engine-clock deadline: once
    /// `deadline_ms` passes, the engine halts the in-flight batched call
    /// for these jobs and returns partial results tagged
    /// [`GenResult::preempted`]. Per-job caps/cancel ride on [`GenJob`].
    pub fn generate_with_deadline(
        &self,
        jobs: Vec<GenJob>,
        deadline_ms: Option<f64>,
    ) -> Result<Vec<GenResult>> {
        rpc!(self, Generate { jobs: jobs, deadline_ms: deadline_ms })
    }

    /// Score CoT prefixes with the PRM.
    pub fn prm_score(&self, prefixes: Vec<Vec<u32>>) -> Result<Vec<f32>> {
        rpc!(self, PrmScore { prefixes: prefixes })
    }

    /// Queue a generate call without blocking on the reply. All requests
    /// submitted before anyone blocks land on the channel together, so
    /// the engine's scheduler drains them into one coalescing round.
    pub fn submit_generate(
        &self,
        jobs: Vec<GenJob>,
        deadline_ms: Option<f64>,
    ) -> Result<PendingReply<Vec<GenResult>>> {
        let (reply, rx) = channel();
        self.tx
            .send(EngineMsg::Generate {
                jobs,
                deadline_ms,
                reply,
            })
            .map_err(|_| Error::Engine("engine thread is gone".into()))?;
        Ok(PendingReply { rx })
    }

    /// Queue a PRM scoring call without blocking on the reply.
    pub fn submit_prm_score(
        &self,
        prefixes: Vec<Vec<u32>>,
    ) -> Result<PendingReply<Vec<f32>>> {
        let (reply, rx) = channel();
        self.tx
            .send(EngineMsg::PrmScore { prefixes, reply })
            .map_err(|_| Error::Engine("engine thread is gone".into()))?;
        Ok(PendingReply { rx })
    }

    /// A handle with no engine behind it: every call fails with an
    /// engine-gone error. Step machines never touch the engine directly
    /// (they express work as yields), so tests can drive them with
    /// synthetic inputs against this handle.
    pub fn disconnected() -> EngineHandle {
        let (tx, _rx) = channel();
        EngineHandle { tx }
    }

    /// Embed queries.
    pub fn embed(&self, kind: EmbedKind, queries: Vec<Vec<u32>>) -> Result<Vec<Vec<f32>>> {
        rpc!(self, Embed { kind: kind, queries: queries })
    }

    /// Probe forward (logits) with the engine's current probe params.
    pub fn probe_fwd(&self, feats: Vec<Vec<f32>>) -> Result<Vec<f32>> {
        rpc!(self, ProbeFwd { feats: feats })
    }

    /// Train the probe; the engine keeps (and returns) the best params.
    #[allow(clippy::too_many_arguments)]
    pub fn probe_train(
        &self,
        train_feats: Vec<Vec<f32>>,
        train_labels: Vec<f32>,
        val_feats: Vec<Vec<f32>>,
        val_labels: Vec<f32>,
        epochs: usize,
        patience: usize,
    ) -> Result<ProbeTrainReport> {
        rpc!(
            self,
            ProbeTrain {
                train_feats: train_feats,
                train_labels: train_labels,
                val_feats: val_feats,
                val_labels: val_labels,
                epochs: epochs,
                patience: patience,
            }
        )
    }

    /// Replace probe parameters (e.g. from a saved checkpoint).
    pub fn probe_load(&self, params: Vec<f32>) -> Result<()> {
        rpc!(self, ProbeLoad { params: params })
    }

    /// Engine diagnostics as JSON.
    pub fn info(&self) -> Result<Value> {
        rpc!(self, Info {})
    }
}

/// Owns the engine thread; shuts it down on drop.
pub struct Engine {
    handle: EngineHandle,
    join: Option<JoinHandle<()>>,
    pub metrics: Arc<EngineMetrics>,
    pub clock: SharedClock,
}

impl Engine {
    /// Spawn the engine thread and wait until artifacts are loaded.
    pub fn start(cfg: &Config) -> Result<Engine> {
        let clock: SharedClock = if cfg.engine.sim_clock {
            clock::sim_clock()
        } else {
            clock::real_clock()
        };
        Self::start_with_clock(cfg, clock)
    }

    pub fn start_with_clock(cfg: &Config, clock: SharedClock) -> Result<Engine> {
        let metrics = Arc::new(EngineMetrics::new());
        let (tx, rx) = channel();
        let (ready_tx, ready_rx) = channel();
        let artifacts = cfg.paths.artifacts.clone();
        let seed = cfg.seed;
        let thread_clock = clock.clone();
        let thread_metrics = metrics.clone();
        let join = std::thread::Builder::new()
            .name("ttc-engine".into())
            .spawn(move || {
                match EngineThread::new(&artifacts, thread_clock, thread_metrics, seed) {
                    Ok(engine) => {
                        let _ = ready_tx.send(Ok(()));
                        engine.serve(rx);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                }
            })
            .map_err(|e| Error::Engine(format!("cannot spawn engine thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Engine("engine thread died during startup".into()))??;
        log_info!("engine started (artifacts: {})", cfg.paths.artifacts.display());
        Ok(Engine {
            handle: EngineHandle { tx },
            join: Some(join),
            metrics,
            clock,
        })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(EngineMsg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
