//! Batch planning: packing sequence jobs into bucket-shaped executable
//! calls.
//!
//! Pure logic (no PJRT) so it is unit- and property-testable. The planner
//! groups jobs by compatibility key — generation kind, padded-length
//! bucket and temperature — then splits each group into batches no larger
//! than the biggest bucket, choosing for each batch the smallest bucket
//! that fits (padding waste is tracked by [`crate::metrics`]).

use crate::engine::protocol::{GenJob, GenKind};

/// One planned executable call.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchPlan {
    /// Indices into the original job list, in row order.
    pub job_indices: Vec<usize>,
    /// Batch bucket (rows in the executable shape).
    pub bucket: usize,
    /// Prompt length bucket (columns).
    pub len_bucket: usize,
    pub kind: GenKind,
    pub temperature: f32,
    /// Upper bound on decode steps this call needs: the largest per-job
    /// `max_new_tokens` among its rows, or `None` when any row is
    /// uncapped (the executable's own limit applies). The engine's
    /// accounting loop stops charging decode steps past this bound.
    pub max_steps: Option<usize>,
}

impl BatchPlan {
    /// Padding rows in this call.
    pub fn padding(&self) -> usize {
        self.bucket - self.job_indices.len()
    }
}

/// Compute the smallest bucket ≥ `n`, or the largest bucket if none fits.
pub fn pick_bucket(buckets: &[usize], n: usize) -> usize {
    debug_assert!(!buckets.is_empty());
    for &b in buckets {
        if b >= n {
            return b;
        }
    }
    *buckets.last().unwrap()
}

/// Plan executable calls for a set of jobs.
///
/// `batch_buckets` and `len_buckets` must be sorted ascending.
/// `query_len` is the (single) padded length for full generation.
pub fn plan_batches(
    jobs: &[GenJob],
    batch_buckets: &[usize],
    len_buckets: &[usize],
    query_len: usize,
) -> Vec<BatchPlan> {
    // group key: (kind, len bucket, temperature bits)
    let mut groups: Vec<((GenKind, usize, u32), Vec<usize>)> = Vec::new();
    for (i, job) in jobs.iter().enumerate() {
        let len_bucket = match job.kind {
            GenKind::Full => query_len,
            GenKind::Chunk => pick_bucket(len_buckets, job.tokens.len()),
        };
        let key = (job.kind, len_bucket, job.temperature.to_bits());
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, v)) => v.push(i),
            None => groups.push((key, vec![i])),
        }
    }

    let max_bucket = *batch_buckets.last().unwrap();
    let mut plans = Vec::new();
    for ((kind, len_bucket, temp_bits), indices) in groups {
        for chunk in indices.chunks(max_bucket) {
            // a single uncapped row forces the whole call to run to the
            // executable's own limit; otherwise the largest cap bounds it
            let mut max_steps = Some(0usize);
            for &i in chunk {
                max_steps = match (max_steps, jobs[i].max_new_tokens) {
                    (Some(acc), Some(cap)) => Some(acc.max(cap)),
                    _ => None,
                };
            }
            plans.push(BatchPlan {
                job_indices: chunk.to_vec(),
                bucket: pick_bucket(batch_buckets, chunk.len()),
                len_bucket,
                kind,
                temperature: f32::from_bits(temp_bits),
                max_steps,
            });
        }
    }
    plans
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, gen_vec, prop_assert};
    use crate::util::rng::Rng;

    const BUCKETS: &[usize] = &[1, 4, 8, 16, 32];
    const LENS: &[usize] = &[32, 64, 96, 128];

    fn job(n_tokens: usize, kind: GenKind, temp: f32) -> GenJob {
        GenJob::new(vec![2; n_tokens], kind, temp)
    }

    #[test]
    fn pick_bucket_basics() {
        assert_eq!(pick_bucket(BUCKETS, 1), 1);
        assert_eq!(pick_bucket(BUCKETS, 2), 4);
        assert_eq!(pick_bucket(BUCKETS, 16), 16);
        assert_eq!(pick_bucket(BUCKETS, 17), 32);
        assert_eq!(pick_bucket(BUCKETS, 99), 32); // clamped; caller splits
    }

    #[test]
    fn groups_by_kind_and_len() {
        let jobs = vec![
            job(10, GenKind::Full, 0.8),
            job(40, GenKind::Chunk, 0.8),
            job(12, GenKind::Full, 0.8),
            job(90, GenKind::Chunk, 0.8),
            job(41, GenKind::Chunk, 0.8),
        ];
        let plans = plan_batches(&jobs, BUCKETS, LENS, 32);
        // full jobs together; chunk l64 jobs (40, 41) together; chunk l96 alone
        assert_eq!(plans.len(), 3);
        let full = plans.iter().find(|p| p.kind == GenKind::Full).unwrap();
        assert_eq!(full.job_indices, vec![0, 2]);
        assert_eq!(full.bucket, 4);
        assert_eq!(full.len_bucket, 32);
        let c64 = plans
            .iter()
            .find(|p| p.kind == GenKind::Chunk && p.len_bucket == 64)
            .unwrap();
        assert_eq!(c64.job_indices, vec![1, 4]);
    }

    #[test]
    fn splits_oversized_groups() {
        let jobs: Vec<GenJob> = (0..70).map(|_| job(8, GenKind::Full, 0.8)).collect();
        let plans = plan_batches(&jobs, BUCKETS, LENS, 32);
        assert_eq!(plans.len(), 3); // 32 + 32 + 6
        assert_eq!(plans[0].bucket, 32);
        assert_eq!(plans[2].job_indices.len(), 6);
        assert_eq!(plans[2].bucket, 8);
    }

    #[test]
    fn different_temperatures_do_not_mix() {
        let jobs = vec![job(8, GenKind::Full, 0.8), job(8, GenKind::Full, 0.5)];
        let plans = plan_batches(&jobs, BUCKETS, LENS, 32);
        assert_eq!(plans.len(), 2);
    }

    #[test]
    fn max_steps_is_largest_cap() {
        let jobs = vec![
            job(8, GenKind::Full, 0.8).with_max_new_tokens(5),
            job(8, GenKind::Full, 0.8).with_max_new_tokens(17),
            job(8, GenKind::Full, 0.8).with_max_new_tokens(3),
        ];
        let plans = plan_batches(&jobs, BUCKETS, LENS, 32);
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].max_steps, Some(17));
    }

    #[test]
    fn uncapped_row_unbounds_the_call() {
        let jobs = vec![
            job(8, GenKind::Full, 0.8).with_max_new_tokens(5),
            job(8, GenKind::Full, 0.8), // no cap
        ];
        let plans = plan_batches(&jobs, BUCKETS, LENS, 32);
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].max_steps, None);
    }

    // ---- properties ----

    fn random_jobs(rng: &mut Rng) -> Vec<GenJob> {
        gen_vec(rng, 0..80, |r| {
            let kind = if r.below(2) == 0 {
                GenKind::Full
            } else {
                GenKind::Chunk
            };
            let n = match kind {
                GenKind::Full => r.range(4, 32) as usize,
                GenKind::Chunk => r.range(8, 128) as usize,
            };
            let temp = if r.below(4) == 0 { 0.5 } else { 0.8 };
            job(n, kind, temp)
        })
    }

    #[test]
    fn prop_no_job_lost_or_duplicated() {
        forall("batcher conserves jobs", 150, random_jobs, |jobs| {
            let plans = plan_batches(jobs, BUCKETS, LENS, 32);
            let mut seen = vec![0usize; jobs.len()];
            for p in &plans {
                for &i in &p.job_indices {
                    seen[i] += 1;
                }
            }
            prop_assert(
                seen.iter().all(|&c| c == 1),
                format!("job multiplicities {seen:?}"),
            )
        });
    }

    #[test]
    fn prop_capacity_and_fit() {
        forall("batches fit buckets", 150, random_jobs, |jobs| {
            let plans = plan_batches(jobs, BUCKETS, LENS, 32);
            for p in &plans {
                prop_assert(
                    p.job_indices.len() <= p.bucket,
                    format!("overfull batch {p:?}"),
                )?;
                prop_assert(
                    BUCKETS.contains(&p.bucket),
                    format!("non-bucket size {p:?}"),
                )?;
                for &i in &p.job_indices {
                    let need = match jobs[i].kind {
                        GenKind::Full => 32,
                        GenKind::Chunk => jobs[i].tokens.len(),
                    };
                    prop_assert(
                        need <= p.len_bucket,
                        format!("prompt {need} exceeds len bucket {}", p.len_bucket),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_homogeneous_batches() {
        forall("batches are homogeneous", 100, random_jobs, |jobs| {
            let plans = plan_batches(jobs, BUCKETS, LENS, 32);
            for p in &plans {
                for &i in &p.job_indices {
                    prop_assert(jobs[i].kind == p.kind, "kind mismatch".to_string())?;
                    prop_assert(
                        jobs[i].temperature == p.temperature,
                        "temperature mismatch".to_string(),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_padding_bounded() {
        // padding waste per batch is < half the bucket except for the
        // smallest bucket (bucket 1 has zero padding by construction)
        forall("padding reasonable", 100, random_jobs, |jobs| {
            let plans = plan_batches(jobs, BUCKETS, LENS, 32);
            for p in &plans {
                let n = p.job_indices.len();
                // smallest bucket ≥ n means previous bucket < n, so
                // padding = bucket - n < bucket / 2 for power-of-2-ish
                // ladders except bucket 4 with n=2 (pad 2). Allow pad <= n+1.
                prop_assert(
                    p.padding() <= n + 1,
                    format!("excess padding: {} jobs in bucket {}", n, p.bucket),
                )?;
            }
            Ok(())
        });
    }
}
