//! Batch planning: packing sequence jobs into bucket-shaped executable
//! calls, deadline-aware.
//!
//! Pure logic (no PJRT) so it is unit- and property-testable. The planner
//! groups jobs by compatibility key — generation kind, padded-length
//! bucket and temperature — then splits each group into bucket-sized
//! *bins* chosen by a padding-minimizing packing ([`pack_bins`]) instead
//! of greedy max-bucket chunking, and finally orders the planned calls
//! earliest-deadline-first ([`order_plans_edf`]) so a near-deadline
//! request is never stuck behind bulk batch work. Padding waste is
//! tracked by [`crate::metrics`].

use crate::engine::protocol::{GenJob, GenKind};

/// One planned executable call.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchPlan {
    /// Indices into the original job list, in row order.
    pub job_indices: Vec<usize>,
    /// Batch bucket (rows in the executable shape).
    pub bucket: usize,
    /// Prompt length bucket (columns).
    pub len_bucket: usize,
    pub kind: GenKind,
    pub temperature: f32,
    /// Upper bound on decode steps this call needs: the largest per-job
    /// `max_new_tokens` among its rows, or `None` when any row is
    /// uncapped (the executable's own limit applies). The engine's
    /// accounting loop stops charging decode steps past this bound.
    pub max_steps: Option<usize>,
}

impl BatchPlan {
    /// Padding rows in this call.
    pub fn padding(&self) -> usize {
        self.bucket - self.job_indices.len()
    }
}

/// Compute the smallest bucket ≥ `n`, or the largest bucket if none fits.
pub fn pick_bucket(buckets: &[usize], n: usize) -> usize {
    debug_assert!(!buckets.is_empty());
    for &b in buckets {
        if b >= n {
            return b;
        }
    }
    *buckets.last().unwrap()
}

/// Cost of launching one extra executable call, expressed in padded-row
/// equivalents. The packing below minimizes `padding + COST·calls`: with
/// pure padding minimization every group would shatter into bucket-1
/// calls (zero padding, maximal per-call overhead); with pure
/// call-minimization every group would ride the single smallest covering
/// bucket (the old greedy behavior — up to `max_bucket/2 − 1` padded
/// rows). Four rows per call sits where one extra call must save at
/// least half a small bucket of padding to pay for itself.
const CALL_COST_ROWS: usize = 4;

/// Partition `n` jobs into bucket-sized bins minimizing
/// `total_padding + CALL_COST_ROWS · bins` (ties prefer fewer bins).
/// Returns the chosen bucket capacities, largest first — fill them in
/// order and only the final bin is ever underfull.
///
/// Greedy max-bucket chunking pads `n = 20` up to a 32-bucket (12 padded
/// rows); this packing returns `[16, 4]` (zero padding, one extra call).
pub fn pack_bins(n: usize, buckets: &[usize]) -> Vec<usize> {
    debug_assert!(!buckets.is_empty());
    if n == 0 {
        return Vec::new();
    }
    // dp[k] = (cost, bins, bucket of the last bin) to cover exactly k jobs
    let mut dp: Vec<(usize, usize, usize)> = vec![(usize::MAX, usize::MAX, 0); n + 1];
    dp[0] = (0, 0, 0);
    for k in 1..=n {
        for &b in buckets {
            let prev = k.saturating_sub(b);
            let (prev_cost, prev_bins, _) = dp[prev];
            if prev_cost == usize::MAX {
                continue;
            }
            let used = k - prev; // rows of this bin actually occupied
            let cost = prev_cost + (b - used) + CALL_COST_ROWS;
            let bins = prev_bins + 1;
            if (cost, bins) < (dp[k].0, dp[k].1) {
                dp[k] = (cost, bins, b);
            }
        }
    }
    let mut out = Vec::with_capacity(dp[n].1);
    let mut k = n;
    while k > 0 {
        let b = dp[k].2;
        out.push(b);
        k = k.saturating_sub(b);
    }
    out.sort_unstable_by(|a, b| b.cmp(a));
    out
}

/// The padded-length bucket a job decodes under: the single `query_len`
/// for full generation, the smallest covering chunk bucket otherwise.
pub fn job_len_bucket(job: &GenJob, len_buckets: &[usize], query_len: usize) -> usize {
    match job.kind {
        GenKind::Full => query_len,
        GenKind::Chunk => pick_bucket(len_buckets, job.tokens.len()),
    }
}

/// Slot-admission policy for the continuous decode path: pick which
/// queued job should fill a freed slot of a *running* session.
///
/// `queued` holds candidate indices into `jobs`, in arrival order. A job
/// is compatible when its generation kind, temperature and padded-length
/// bucket all match the session's executable shape (rows of one call
/// must stay homogeneous, exactly as in [`plan_batches_edf`]). Among
/// compatible jobs the earliest deadline wins; ties keep arrival order —
/// the same EDF tiebreak the round planner applies, so mid-decode
/// admission never reorders against it. Returns the *position in
/// `queued`* (so the caller can `remove` it), or `None` when nothing
/// compatible is waiting.
#[allow(clippy::too_many_arguments)]
pub fn pick_slot_admission(
    jobs: &[GenJob],
    queued: &[usize],
    deadlines: &[f64],
    kind: GenKind,
    len_bucket: usize,
    temperature: f32,
    len_buckets: &[usize],
    query_len: usize,
) -> Option<usize> {
    let mut best: Option<(f64, usize)> = None; // (deadline, position)
    for (pos, &ji) in queued.iter().enumerate() {
        let job = &jobs[ji];
        if job.kind != kind
            || job.temperature.to_bits() != temperature.to_bits()
            || job_len_bucket(job, len_buckets, query_len) != len_bucket
        {
            continue;
        }
        let d = deadlines[ji];
        // strictly-earlier wins; equal keeps the earlier queue position
        if best.map_or(true, |(bd, _)| d < bd) {
            best = Some((d, pos));
        }
    }
    best.map(|(_, pos)| pos)
}

/// Earliest deadline among a plan's rows (`f64::INFINITY` when none).
pub fn plan_deadline(plan: &BatchPlan, deadlines: &[f64]) -> f64 {
    plan.job_indices
        .iter()
        .map(|&i| deadlines[i])
        .fold(f64::INFINITY, f64::min)
}

/// Order planned calls earliest-deadline-first: stable sort by each
/// plan's earliest row deadline, so the call a near-deadline request
/// rides in is dispatched before bulk undeadlined work. Ties (including
/// all-unbudgeted plans) keep their planning order.
pub fn order_plans_edf(plans: &mut [BatchPlan], deadlines: &[f64]) {
    plans.sort_by(|a, b| {
        plan_deadline(a, deadlines)
            .partial_cmp(&plan_deadline(b, deadlines))
            .unwrap_or(std::cmp::Ordering::Equal)
    });
}

/// Plan executable calls for a set of jobs with no deadlines (offline /
/// bench path). Equivalent to [`plan_batches_edf`] with every deadline
/// infinite: bin-packed, original submission order preserved.
pub fn plan_batches(
    jobs: &[GenJob],
    batch_buckets: &[usize],
    len_buckets: &[usize],
    query_len: usize,
) -> Vec<BatchPlan> {
    plan_batches_edf(
        jobs,
        &vec![f64::INFINITY; jobs.len()],
        batch_buckets,
        len_buckets,
        query_len,
    )
}

/// Plan executable calls for a set of jobs under per-job absolute
/// deadlines (`f64::INFINITY` = none; must be `jobs.len()` long).
///
/// `batch_buckets` and `len_buckets` must be sorted ascending.
/// `query_len` is the (single) padded length for full generation.
/// Within each compatibility group, rows are ordered
/// earliest-deadline-first before bin-packing (near-deadline jobs share
/// the first, earliest-dispatched bins), and the returned plans are
/// ordered earliest-deadline-first overall.
pub fn plan_batches_edf(
    jobs: &[GenJob],
    deadlines: &[f64],
    batch_buckets: &[usize],
    len_buckets: &[usize],
    query_len: usize,
) -> Vec<BatchPlan> {
    debug_assert_eq!(jobs.len(), deadlines.len());
    // group key: (kind, len bucket, temperature bits)
    let mut groups: Vec<((GenKind, usize, u32), Vec<usize>)> = Vec::new();
    for (i, job) in jobs.iter().enumerate() {
        let len_bucket = job_len_bucket(job, len_buckets, query_len);
        let key = (job.kind, len_bucket, job.temperature.to_bits());
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, v)) => v.push(i),
            None => groups.push((key, vec![i])),
        }
    }

    let mut plans = Vec::new();
    for ((kind, len_bucket, temp_bits), mut indices) in groups {
        // earliest-deadline rows first; ties keep submission order
        indices.sort_by(|&a, &b| {
            deadlines[a]
                .partial_cmp(&deadlines[b])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let bins = pack_bins(indices.len(), batch_buckets);
        let mut start = 0usize;
        for bucket in bins {
            let take = bucket.min(indices.len() - start);
            let chunk = &indices[start..start + take];
            start += take;
            // a single uncapped row forces the whole call to run to the
            // executable's own limit; otherwise the largest cap bounds it
            let mut max_steps = Some(0usize);
            for &i in chunk {
                max_steps = match (max_steps, jobs[i].max_new_tokens) {
                    (Some(acc), Some(cap)) => Some(acc.max(cap)),
                    _ => None,
                };
            }
            plans.push(BatchPlan {
                job_indices: chunk.to_vec(),
                bucket,
                len_bucket,
                kind,
                temperature: f32::from_bits(temp_bits),
                max_steps,
            });
        }
        debug_assert_eq!(start, indices.len());
    }
    order_plans_edf(&mut plans, deadlines);
    plans
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, gen_vec, prop_assert};
    use crate::util::rng::Rng;

    const BUCKETS: &[usize] = &[1, 4, 8, 16, 32];
    const LENS: &[usize] = &[32, 64, 96, 128];

    fn job(n_tokens: usize, kind: GenKind, temp: f32) -> GenJob {
        GenJob::new(vec![2; n_tokens], kind, temp)
    }

    #[test]
    fn pick_bucket_basics() {
        assert_eq!(pick_bucket(BUCKETS, 1), 1);
        assert_eq!(pick_bucket(BUCKETS, 2), 4);
        assert_eq!(pick_bucket(BUCKETS, 16), 16);
        assert_eq!(pick_bucket(BUCKETS, 17), 32);
        assert_eq!(pick_bucket(BUCKETS, 99), 32); // clamped; caller splits
    }

    #[test]
    fn pack_bins_basics() {
        assert_eq!(pack_bins(0, BUCKETS), Vec::<usize>::new());
        assert_eq!(pack_bins(1, BUCKETS), vec![1]);
        assert_eq!(pack_bins(2, BUCKETS), vec![4]); // 2 padded < 1 extra call
        assert_eq!(pack_bins(16, BUCKETS), vec![16]);
        // greedy would pad 20 up to one 32-bucket (12 padded rows)
        assert_eq!(pack_bins(20, BUCKETS), vec![16, 4]);
        assert_eq!(pack_bins(33, BUCKETS), vec![32, 1]);
        assert_eq!(pack_bins(70, BUCKETS), vec![32, 32, 8]);
    }

    #[test]
    fn pack_bins_single_call_when_padding_cheap() {
        // 5 jobs: bucket 8 pads 3 rows — cheaper than the extra 4+1 call
        assert_eq!(pack_bins(5, BUCKETS), vec![8]);
        // tie on cost (16 alone vs 8+4): fewer calls wins
        assert_eq!(pack_bins(12, BUCKETS), vec![16]);
    }

    #[test]
    fn groups_by_kind_and_len() {
        let jobs = vec![
            job(10, GenKind::Full, 0.8),
            job(40, GenKind::Chunk, 0.8),
            job(12, GenKind::Full, 0.8),
            job(90, GenKind::Chunk, 0.8),
            job(41, GenKind::Chunk, 0.8),
        ];
        let plans = plan_batches(&jobs, BUCKETS, LENS, 32);
        // full jobs together; chunk l64 jobs (40, 41) together; chunk l96 alone
        assert_eq!(plans.len(), 3);
        let full = plans.iter().find(|p| p.kind == GenKind::Full).unwrap();
        assert_eq!(full.job_indices, vec![0, 2]);
        assert_eq!(full.bucket, 4);
        assert_eq!(full.len_bucket, 32);
        let c64 = plans
            .iter()
            .find(|p| p.kind == GenKind::Chunk && p.len_bucket == 64)
            .unwrap();
        assert_eq!(c64.job_indices, vec![1, 4]);
    }

    #[test]
    fn splits_oversized_groups() {
        let jobs: Vec<GenJob> = (0..70).map(|_| job(8, GenKind::Full, 0.8)).collect();
        let plans = plan_batches(&jobs, BUCKETS, LENS, 32);
        assert_eq!(plans.len(), 3); // 32 + 32 + 6
        assert_eq!(plans[0].bucket, 32);
        assert_eq!(plans[2].job_indices.len(), 6);
        assert_eq!(plans[2].bucket, 8);
    }

    #[test]
    fn bin_packing_avoids_max_bucket_padding() {
        // 20 identical jobs: greedy max-bucket chunking would issue one
        // 32-bucket call (12 padded rows); bin-packing issues 16 + 4.
        let jobs: Vec<GenJob> = (0..20).map(|_| job(8, GenKind::Full, 0.8)).collect();
        let plans = plan_batches(&jobs, BUCKETS, LENS, 32);
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].bucket, 16);
        assert_eq!(plans[1].bucket, 4);
        assert_eq!(plans.iter().map(BatchPlan::padding).sum::<usize>(), 0);
    }

    #[test]
    fn different_temperatures_do_not_mix() {
        let jobs = vec![job(8, GenKind::Full, 0.8), job(8, GenKind::Full, 0.5)];
        let plans = plan_batches(&jobs, BUCKETS, LENS, 32);
        assert_eq!(plans.len(), 2);
    }

    #[test]
    fn max_steps_is_largest_cap() {
        let jobs = vec![
            job(8, GenKind::Full, 0.8).with_max_new_tokens(5),
            job(8, GenKind::Full, 0.8).with_max_new_tokens(17),
            job(8, GenKind::Full, 0.8).with_max_new_tokens(3),
        ];
        let plans = plan_batches(&jobs, BUCKETS, LENS, 32);
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].max_steps, Some(17));
    }

    #[test]
    fn uncapped_row_unbounds_the_call() {
        let jobs = vec![
            job(8, GenKind::Full, 0.8).with_max_new_tokens(5),
            job(8, GenKind::Full, 0.8), // no cap
        ];
        let plans = plan_batches(&jobs, BUCKETS, LENS, 32);
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].max_steps, None);
    }

    #[test]
    fn edf_orders_plans_and_rows() {
        // jobs 0..3 undeadlined, job 4 (different temperature group)
        // near its deadline: its plan must be dispatched first
        let mut jobs: Vec<GenJob> = (0..4).map(|_| job(8, GenKind::Full, 0.8)).collect();
        jobs.push(job(8, GenKind::Full, 0.5));
        let mut deadlines = vec![f64::INFINITY; 4];
        deadlines.push(10.0);
        let plans = plan_batches_edf(&jobs, &deadlines, BUCKETS, LENS, 32);
        assert_eq!(plans.len(), 2);
        assert_eq!(plans[0].job_indices, vec![4]);
        assert_eq!(plan_deadline(&plans[0], &deadlines), 10.0);
    }

    // ---- properties ----

    fn random_jobs(rng: &mut Rng) -> Vec<GenJob> {
        gen_vec(rng, 0..80, |r| {
            let kind = if r.below(2) == 0 {
                GenKind::Full
            } else {
                GenKind::Chunk
            };
            let n = match kind {
                GenKind::Full => r.range(4, 32) as usize,
                GenKind::Chunk => r.range(8, 128) as usize,
            };
            let temp = if r.below(4) == 0 { 0.5 } else { 0.8 };
            job(n, kind, temp)
        })
    }

    fn random_deadlines(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n)
            .map(|_| {
                if rng.below(2) == 0 {
                    f64::INFINITY
                } else {
                    rng.f64() * 500.0
                }
            })
            .collect()
    }

    #[test]
    fn prop_no_job_lost_or_duplicated() {
        forall("batcher conserves jobs", 150, random_jobs, |jobs| {
            let plans = plan_batches(jobs, BUCKETS, LENS, 32);
            let mut seen = vec![0usize; jobs.len()];
            for p in &plans {
                for &i in &p.job_indices {
                    seen[i] += 1;
                }
            }
            prop_assert(
                seen.iter().all(|&c| c == 1),
                format!("job multiplicities {seen:?}"),
            )
        });
    }

    #[test]
    fn prop_capacity_and_fit() {
        // bin-packed plans never exceed bucket capacity, for deadlined
        // and undeadlined planning alike
        forall(
            "batches fit buckets",
            150,
            |rng| {
                let jobs = random_jobs(rng);
                let deadlines = random_deadlines(rng, jobs.len());
                (jobs, deadlines)
            },
            |(jobs, deadlines)| {
                let plans = plan_batches_edf(jobs, deadlines, BUCKETS, LENS, 32);
                for p in &plans {
                    prop_assert(
                        p.job_indices.len() <= p.bucket,
                        format!("overfull batch {p:?}"),
                    )?;
                    prop_assert(
                        BUCKETS.contains(&p.bucket),
                        format!("non-bucket size {p:?}"),
                    )?;
                    for &i in &p.job_indices {
                        let need = match jobs[i].kind {
                            GenKind::Full => 32,
                            GenKind::Chunk => jobs[i].tokens.len(),
                        };
                        prop_assert(
                            need <= p.len_bucket,
                            format!("prompt {need} exceeds len bucket {}", p.len_bucket),
                        )?;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_homogeneous_batches() {
        forall("batches are homogeneous", 100, random_jobs, |jobs| {
            let plans = plan_batches(jobs, BUCKETS, LENS, 32);
            for p in &plans {
                for &i in &p.job_indices {
                    prop_assert(jobs[i].kind == p.kind, "kind mismatch".to_string())?;
                    prop_assert(
                        jobs[i].temperature == p.temperature,
                        "temperature mismatch".to_string(),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_padding_bounded() {
        // bin-packing fills every bin but the last of each group, so
        // per-plan padding is never worse than the smallest covering
        // bucket's (pad <= n+1 on this ladder; bucket 1 pads zero)
        forall("padding reasonable", 100, random_jobs, |jobs| {
            let plans = plan_batches(jobs, BUCKETS, LENS, 32);
            for p in &plans {
                let n = p.job_indices.len();
                prop_assert(
                    p.padding() <= n + 1,
                    format!("excess padding: {} jobs in bucket {}", n, p.bucket),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn packing_never_pads_more_than_greedy() {
        // total padding under pack_bins <= the old greedy max-bucket
        // chunking, for every group size up to several buckets' worth
        let max_bucket = *BUCKETS.last().unwrap();
        for n in 0..200usize {
            let packed: usize = pack_bins(n, BUCKETS).iter().sum::<usize>() - n;
            let mut greedy = 0usize;
            let mut left = n;
            while left > 0 {
                let take = left.min(max_bucket);
                greedy += pick_bucket(BUCKETS, take) - take;
                left -= take;
            }
            assert!(
                packed <= greedy,
                "n={n}: packed padding {packed} > greedy {greedy}"
            );
        }
    }

    #[test]
    fn slot_admission_prefers_earliest_deadline() {
        let jobs = vec![
            job(8, GenKind::Full, 0.8),  // 0: compatible, no deadline
            job(8, GenKind::Full, 0.5),  // 1: wrong temperature
            job(40, GenKind::Chunk, 0.8), // 2: wrong kind
            job(8, GenKind::Full, 0.8),  // 3: compatible, deadline 50
            job(8, GenKind::Full, 0.8),  // 4: compatible, deadline 10
        ];
        let deadlines = vec![f64::INFINITY, 5.0, 1.0, 50.0, 10.0];
        let queued = vec![0, 1, 2, 3, 4];
        let pos = pick_slot_admission(
            &jobs, &queued, &deadlines, GenKind::Full, 32, 0.8, LENS, 32,
        );
        assert_eq!(pos, Some(4), "earliest compatible deadline wins");
        // nothing compatible waiting
        let none = pick_slot_admission(
            &jobs, &queued[1..3], &deadlines, GenKind::Full, 32, 0.8, LENS, 32,
        );
        assert_eq!(none, None);
        // deadline tie keeps arrival order
        let tied = pick_slot_admission(
            &jobs, &[3, 0, 4], &vec![7.0; 5], GenKind::Full, 32, 0.8, LENS, 32,
        );
        assert_eq!(tied, Some(0));
    }

    #[test]
    fn prop_slot_admission_compatible_and_edf_minimal() {
        // the admitted job is always shape-compatible with the session
        // and has the minimum deadline among compatible queued jobs;
        // None is returned iff nothing compatible is queued
        forall(
            "slot admission is EDF over compatible jobs",
            200,
            |rng| {
                let jobs = random_jobs(rng);
                let deadlines = random_deadlines(rng, jobs.len());
                let kind = if rng.below(2) == 0 {
                    GenKind::Full
                } else {
                    GenKind::Chunk
                };
                let len_bucket = match kind {
                    GenKind::Full => 32,
                    GenKind::Chunk => LENS[rng.below(LENS.len() as u64) as usize],
                };
                let temp = if rng.below(2) == 0 { 0.5 } else { 0.8 };
                (jobs, deadlines, kind, len_bucket, temp)
            },
            |(jobs, deadlines, kind, len_bucket, temp)| {
                let queued: Vec<usize> = (0..jobs.len()).collect();
                let got = pick_slot_admission(
                    jobs, &queued, deadlines, *kind, *len_bucket, *temp, LENS, 32,
                );
                let compatible: Vec<usize> = queued
                    .iter()
                    .copied()
                    .filter(|&i| {
                        jobs[i].kind == *kind
                            && jobs[i].temperature.to_bits() == temp.to_bits()
                            && job_len_bucket(&jobs[i], LENS, 32) == *len_bucket
                    })
                    .collect();
                match got {
                    None => prop_assert(
                        compatible.is_empty(),
                        format!("returned None with {} compatible jobs", compatible.len()),
                    ),
                    Some(pos) => {
                        let ji = queued[pos];
                        prop_assert(
                            compatible.contains(&ji),
                            format!("admitted incompatible job {ji}"),
                        )?;
                        let min = compatible
                            .iter()
                            .map(|&i| deadlines[i])
                            .fold(f64::INFINITY, f64::min);
                        prop_assert(
                            deadlines[ji] == min,
                            format!("admitted deadline {} > min {min}", deadlines[ji]),
                        )
                    }
                }
            },
        );
    }

    #[test]
    fn prop_edf_no_starvation() {
        // after EDF ordering no plan precedes a strictly earlier-deadline
        // plan, and the globally earliest deadline rides the first plan —
        // a near-deadline request is never starved behind bulk work
        forall(
            "EDF never starves a deadline",
            150,
            |rng| {
                let jobs = random_jobs(rng);
                let deadlines = random_deadlines(rng, jobs.len());
                (jobs, deadlines)
            },
            |(jobs, deadlines)| {
                let plans = plan_batches_edf(jobs, deadlines, BUCKETS, LENS, 32);
                let keys: Vec<f64> = plans.iter().map(|p| plan_deadline(p, deadlines)).collect();
                for w in keys.windows(2) {
                    prop_assert(
                        w[0] <= w[1],
                        format!("plans out of EDF order: {keys:?}"),
                    )?;
                }
                if let Some(global_min) = deadlines.iter().cloned().fold(None::<f64>, |m, d| {
                    Some(m.map_or(d, |m| m.min(d)))
                }) {
                    if !plans.is_empty() {
                        prop_assert(
                            keys[0] == global_min,
                            format!("first plan deadline {} != global min {global_min}", keys[0]),
                        )?;
                    }
                }
                Ok(())
            },
        );
    }
}
