//! `ttc` — leader binary for the latency- and token-aware test-time
//! compute router.
//!
//! Subcommands:
//!
//! | command | purpose |
//! |---|---|
//! | `taskgen` | emit synthetic corpora + vocab (consumed by `make artifacts`) |
//! | `collect` | build the evaluation matrix (query × strategy × repeat) |
//! | `train-probe` | train + Platt-calibrate the accuracy probe (AOT'd Adam) |
//! | `figures` | regenerate the paper's figures from the matrix |
//! | `serve` | run the adaptive serving driver with a load generator (sharded engine pool via `--engines N`, `--backend device\|sim\|remote`, `--remote host:port,...`) |
//! | `engine-serve` | expose a local engine fleet over TCP for remote `serve` clients (`docs/remote.md`) |
//! | `pipeline` | collect → train-probe → figures, end to end |
//! | `info` | print artifact/runtime diagnostics |

use ttc::cli::Args;
use ttc::error::Result;
use ttc::log_info;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() || raw[0] == "--help" || raw[0] == "help" {
        print_help();
        std::process::exit(if raw.is_empty() { 2 } else { 0 });
    }
    if let Err(e) = dispatch(&raw) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    eprintln!(
        "ttc — latency & token-aware test-time compute router\n\
         \n\
         usage: ttc <subcommand> [options]\n\
         \n\
         subcommands:\n\
           taskgen      --out DIR [--seed N] [--lm-docs N] [--prm-examples N]\n\
                        [--queries-train N] [--queries-calib N] [--queries-test N]\n\
           collect      [--config F] [--artifacts DIR] [--results DIR] [--split S] [--sim]\n\
           train-probe  [--config F] [--artifacts DIR] [--results DIR] [--embedding E]\n\
           figures      [--config F] [--results DIR] [--fig ID|all]\n\
           serve        [--config F] [--artifacts DIR] [--rate R] [--requests N]\n\
                        [--lambda-t X] [--lambda-l X] [--strategy S] [--sim]\n\
                        [--engines N] [--backend device|sim|remote]\n\
                        [--remote host:port[,host:port...]] [--wire-codec json|binary]\n\
                        [--deadline-ms X] [--max-tokens N]\n\
                        [--budget-mix W:SPEC,... e.g. 30:d500,30:d5000,40:unlimited]\n\
                        [--arrivals poisson|gamma:SHAPE|onoff:BURST:IDLE_S]\n\
                        [--chains N] [--chain-budget SPEC e.g. d8000t1200]\n\
                        [--trace FILE.json]  (agentic chains: docs/chains.md)\n\
                        [--cache] [--cache-entries N] [--cache-shards N]\n\
           engine-serve [--config F] [--addr HOST:PORT] [--backend device|sim]\n\
                        [--engines N] [--sim] [--wire-codec json|binary]\n\
                        [--cache] [--cache-entries N] [--cache-shards N]\n\
           pipeline     [--config F] [--artifacts DIR] [--out DIR] [--quick]\n\
           info         [--artifacts DIR]"
    );
    // registry-driven: newly registered decoding methods show up here
    // (and in `--strategy` ids) with no CLI edits
    eprintln!("\ndecoding methods (--strategy <name>@<params>):");
    for m in ttc::strategies::registry::all() {
        let example = ttc::strategies::Strategy::new(m.name(), m.default_params()).id();
        eprintln!("  {:<14} {}  (e.g. {})", m.name(), m.describe(), example);
    }
}

fn dispatch(raw: &[String]) -> Result<()> {
    match raw[0].as_str() {
        "taskgen" => cmd_taskgen(raw),
        "collect" => ttc::server::commands::cmd_collect(raw),
        "train-probe" => ttc::server::commands::cmd_train_probe(raw),
        "figures" => ttc::server::commands::cmd_figures(raw),
        "serve" => ttc::server::commands::cmd_serve(raw),
        "engine-serve" => ttc::server::commands::cmd_engine_serve(raw),
        "pipeline" => ttc::server::commands::cmd_pipeline(raw),
        "info" => ttc::server::commands::cmd_info(raw),
        other => {
            print_help();
            Err(ttc::Error::Config(format!("unknown subcommand '{other}'")))
        }
    }
}

fn cmd_taskgen(raw: &[String]) -> Result<()> {
    let args = Args::parse(
        raw,
        &[
            "out",
            "seed",
            "lm-docs",
            "prm-examples",
            "queries-train",
            "queries-calib",
            "queries-test",
        ],
        &[],
    )?;
    let out = std::path::PathBuf::from(args.str_or("out", "artifacts/data"));
    let defaults = ttc::taskgen::CorpusConfig::default();
    let cfg = ttc::taskgen::CorpusConfig {
        lm_docs: args.usize_or("lm-docs", defaults.lm_docs)?,
        prm_examples: args.usize_or("prm-examples", defaults.prm_examples)?,
        queries_train: args.usize_or("queries-train", defaults.queries_train)?,
        queries_calib: args.usize_or("queries-calib", defaults.queries_calib)?,
        queries_test: args.usize_or("queries-test", defaults.queries_test)?,
        seed: args.u64_or("seed", defaults.seed)?,
    };
    let n = ttc::taskgen::emit_all(&out, &cfg)?;
    log_info!(
        "taskgen: wrote {n} files to {} (lm_docs={}, prm={}, queries={}/{}/{})",
        out.display(),
        cfg.lm_docs,
        cfg.prm_examples,
        cfg.queries_train,
        cfg.queries_calib,
        cfg.queries_test
    );
    Ok(())
}
