//! PRM scoring throughput — called once per beam-search round and once
//! per best-of-N aggregation, so it bounds beam-search latency together
//! with chunk generation. Requires artifacts (SKIPs otherwise).

use ttc::config::Config;
use ttc::engine::Engine;
use ttc::tokenizer::Tokenizer;
use ttc::util::bench::{bench, header};

fn main() {
    header("bench_prm");
    let cfg = Config::default();
    if !cfg.paths.artifacts.join("hlo_index.json").exists() {
        println!("bench,SKIP_no_artifacts,0,0,0,0");
        return;
    }
    let engine = Engine::start(&cfg).expect("engine start");
    let handle = engine.handle();
    let tok = Tokenizer::new();
    let prefix = tok
        .encode("Q:7+8-2+8=?\nS:7+8=5;5-2=3;")
        .unwrap();

    for n in [1usize, 8, 32] {
        let prefixes: Vec<Vec<u32>> = (0..n).map(|_| prefix.clone()).collect();
        bench(&format!("prm_score_b{n}"), || {
            std::hint::black_box(handle.prm_score(prefixes.clone()).unwrap());
        });
    }

    // four concurrent scorers of 8 prefixes each: the engine scheduler
    // coalesces them into shared bucket-shaped calls (one b32 instead of
    // four padded b8s when their messages land in the same round)
    bench("prm_score_4x8_concurrent", || {
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let handle = handle.clone();
                let prefix = prefix.clone();
                scope.spawn(move || {
                    let prefixes: Vec<Vec<u32>> = (0..8).map(|_| prefix.clone()).collect();
                    std::hint::black_box(handle.prm_score(prefixes).unwrap());
                });
            }
        });
    });

    // namespaced so these PRM-only numbers never collide with
    // bench_engine's mixed-workload stats in BENCH_<sha>.json (the
    // gate's ceilings target the mixed workload)
    let info = handle.info().unwrap();
    let metrics = info.req("metrics").expect("engine metrics");
    for key in ["prm_padding_waste", "coalesced_prm"] {
        if let Ok(v) = metrics.req_f64(key) {
            println!("stat,bench_prm_{key},{v}");
        }
    }
}
