//! PRM scoring throughput — called once per beam-search round and once
//! per best-of-N aggregation, so it bounds beam-search latency together
//! with chunk generation. Requires artifacts (SKIPs otherwise).

use ttc::config::Config;
use ttc::engine::Engine;
use ttc::tokenizer::Tokenizer;
use ttc::util::bench::{bench, header};

fn main() {
    header("bench_prm");
    let cfg = Config::default();
    if !cfg.paths.artifacts.join("hlo_index.json").exists() {
        println!("bench,SKIP_no_artifacts,0,0,0,0");
        return;
    }
    let engine = Engine::start(&cfg).expect("engine start");
    let handle = engine.handle();
    let tok = Tokenizer::new();
    let prefix = tok
        .encode("Q:7+8-2+8=?\nS:7+8=5;5-2=3;")
        .unwrap();

    for n in [1usize, 8, 32] {
        let prefixes: Vec<Vec<u32>> = (0..n).map(|_| prefix.clone()).collect();
        bench(&format!("prm_score_b{n}"), || {
            std::hint::black_box(handle.prm_score(prefixes.clone()).unwrap());
        });
    }
}
