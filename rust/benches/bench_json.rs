//! Substrate bench: JSON parse/serialize throughput (matrix files are
//! JSONL; collection appends one record per strategy run).

use ttc::util::bench::{bench, header};
use ttc::util::json::{parse, Value};

fn main() {
    header("bench_json");
    let record = Value::obj()
        .with("query_id", "queries_test-123")
        .with("split", "test")
        .with("strategy", "beam@4x2c12")
        .with("repeat", 2usize)
        .with("k", 5usize)
        .with("correct", true)
        .with("tokens", 812usize)
        .with("latency_ms", 4312.55);
    let line = record.dumps();

    bench("json_serialize_matrix_record", || {
        std::hint::black_box(record.dumps());
    });
    bench("json_parse_matrix_record", || {
        std::hint::black_box(parse(&line).unwrap());
    });

    // a whole 1k-line matrix chunk
    let chunk: String = (0..1000).map(|_| format!("{line}\n")).collect();
    bench("json_parse_1k_lines", || {
        let mut n = 0;
        for l in chunk.lines() {
            n += parse(l).unwrap().as_obj().map(|o| o.len()).unwrap_or(0);
        }
        std::hint::black_box(n);
    });
}
