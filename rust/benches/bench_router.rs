//! L3 hot path: the per-query strategy selection (`select_offline` over
//! the full strategy space) plus feature construction — this sits on the
//! request path before ANY generation, so it must be microseconds. The
//! space comes from `SpaceConfig::default()`, so every registered method
//! (incl. `mv_early` / `beam_latency`) is covered, and per-method
//! feature-row benches track each method's selection-path cost.

use ttc::config::SpaceConfig;
use ttc::costmodel::CostEstimate;
use ttc::probe::FeatureBuilder;
use ttc::router::{pick_feasible, select_offline, Lambdas, StrategyScore};
use ttc::strategies::{registry, Strategy};
use ttc::util::bench::{bench, header};
use ttc::util::rng::Rng;

fn main() {
    header("bench_router");
    let strategies = Strategy::enumerate(&SpaceConfig::default());
    println!(
        "# space: {} strategies over {} methods: {:?}",
        strategies.len(),
        registry::len(),
        registry::all().iter().map(|m| m.name()).collect::<Vec<_>>()
    );
    let n = strategies.len();
    let mut rng = Rng::new(11, 0);
    let probs: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
    let costs: Vec<CostEstimate> = (0..n)
        .map(|_| CostEstimate {
            tokens: rng.f64() * 1000.0,
            latency_ms: rng.f64() * 10000.0,
        })
        .collect();
    let lambdas = Lambdas::new(1e-4, 1e-5);

    bench("select_offline_full_space", || {
        std::hint::black_box(select_offline(&probs, &costs, lambdas));
    });

    // budget-aware selection: deadline feasibility filter + argmax over
    // the full space (the serving hot path with a per-request deadline)
    let scores: Vec<StrategyScore> = strategies
        .iter()
        .zip(&probs)
        .zip(&costs)
        .map(|((s, &acc_hat), &cost)| StrategyScore {
            strategy: s.clone(),
            acc_hat,
            full_latency_ms: cost.latency_ms,
            cost,
            utility: lambdas.utility(acc_hat, &cost),
        })
        .collect();
    bench("pick_feasible_deadline500ms", || {
        std::hint::black_box(pick_feasible(&scores, Some(500.0)));
    });

    let fb = FeatureBuilder::new(96, 10);
    let emb = vec![0.1f32; 96];
    bench("feature_rows_full_space", || {
        let rows: Vec<Vec<f32>> = strategies.iter().map(|s| fb.build(&emb, s, 14)).collect();
        std::hint::black_box(rows);
    });

    // per-method feature-row cost (one row per registered method)
    for m in registry::all() {
        let s = Strategy::new(m.name(), m.default_params());
        bench(&format!("feature_row_{}", m.name()), || {
            std::hint::black_box(fb.build(&emb, &s, 14));
        });
    }

    // λ-grid sweep cost (a full figure panel)
    let grid: Vec<f64> = (0..16).map(|i| 1e-6 * 2f64.powi(i)).collect();
    bench("lambda_sweep_16_points", || {
        let mut acc = 0usize;
        for &lt in &grid {
            acc += select_offline(&probs, &costs, Lambdas::new(lt, 0.0));
        }
        std::hint::black_box(acc);
    });
}
