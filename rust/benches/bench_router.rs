//! L3 hot path: the per-query strategy selection (`select_offline` over
//! the full strategy space) plus feature construction — this sits on the
//! request path before ANY generation, so it must be microseconds.

use ttc::config::SpaceConfig;
use ttc::costmodel::CostEstimate;
use ttc::probe::FeatureBuilder;
use ttc::router::{select_offline, Lambdas};
use ttc::strategies::Strategy;
use ttc::util::bench::{bench, header};
use ttc::util::rng::Rng;

fn main() {
    header("bench_router");
    let strategies = Strategy::enumerate(&SpaceConfig::default());
    let n = strategies.len();
    let mut rng = Rng::new(11, 0);
    let probs: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
    let costs: Vec<CostEstimate> = (0..n)
        .map(|_| CostEstimate {
            tokens: rng.f64() * 1000.0,
            latency_ms: rng.f64() * 10000.0,
        })
        .collect();
    let lambdas = Lambdas::new(1e-4, 1e-5);

    bench("select_offline_full_space", || {
        std::hint::black_box(select_offline(&probs, &costs, lambdas));
    });

    let fb = FeatureBuilder::new(96, 10);
    let emb = vec![0.1f32; 96];
    bench("feature_rows_full_space", || {
        let rows: Vec<Vec<f32>> = strategies.iter().map(|s| fb.build(&emb, s, 14)).collect();
        std::hint::black_box(rows);
    });

    // λ-grid sweep cost (a full figure panel)
    let grid: Vec<f64> = (0..16).map(|i| 1e-6 * 2f64.powi(i)).collect();
    bench("lambda_sweep_16_points", || {
        let mut acc = 0usize;
        for &lt in &grid {
            acc += select_offline(&probs, &costs, Lambdas::new(lt, 0.0));
        }
        std::hint::black_box(acc);
    });
}
