//! L3 hot path: batch planning (runs on every generate round).

use ttc::engine::{pack_bins, plan_batches, plan_batches_edf, GenJob, GenKind};
use ttc::util::bench::{bench, header};
use ttc::util::rng::Rng;

fn jobs(n: usize, seed: u64) -> Vec<GenJob> {
    let mut rng = Rng::new(seed, 0);
    (0..n)
        .map(|_| {
            let kind = if rng.below(2) == 0 {
                GenKind::Full
            } else {
                GenKind::Chunk
            };
            let len = match kind {
                GenKind::Full => rng.range(8, 32) as usize,
                GenKind::Chunk => rng.range(16, 128) as usize,
            };
            let job = GenJob::new(vec![2; len], kind, 0.8);
            if rng.below(2) == 0 {
                job.with_max_new_tokens(rng.range(4, 64) as usize)
            } else {
                job
            }
        })
        .collect()
}

fn main() {
    header("bench_batcher");
    let buckets = [1usize, 4, 8, 16, 32];
    let lens = [32usize, 64, 96, 128];
    for n in [4usize, 32, 128] {
        let js = jobs(n, n as u64);
        bench(&format!("plan_batches_{n}_jobs"), || {
            std::hint::black_box(plan_batches(&js, &buckets, &lens, 32));
        });
    }

    // deadline-aware planning (per-job sort + EDF plan ordering)
    for n in [32usize, 128] {
        let js = jobs(n, n as u64);
        let mut rng = Rng::new(7, n as u64);
        let deadlines: Vec<f64> = (0..n)
            .map(|_| {
                if rng.below(2) == 0 {
                    f64::INFINITY
                } else {
                    rng.f64() * 500.0
                }
            })
            .collect();
        bench(&format!("plan_batches_edf_{n}_jobs"), || {
            std::hint::black_box(plan_batches_edf(&js, &deadlines, &buckets, &lens, 32));
        });
    }

    // bin-packing alone (the DP the planner runs per group)
    bench("pack_bins_0_to_128", || {
        for n in 0..128usize {
            std::hint::black_box(pack_bins(n, &buckets));
        }
    });
}
