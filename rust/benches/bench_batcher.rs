//! L3 hot path: batch planning (runs on every generate round).

use ttc::engine::{plan_batches, GenJob, GenKind};
use ttc::util::bench::{bench, header};
use ttc::util::rng::Rng;

fn jobs(n: usize, seed: u64) -> Vec<GenJob> {
    let mut rng = Rng::new(seed, 0);
    (0..n)
        .map(|_| {
            let kind = if rng.below(2) == 0 {
                GenKind::Full
            } else {
                GenKind::Chunk
            };
            let len = match kind {
                GenKind::Full => rng.range(8, 32) as usize,
                GenKind::Chunk => rng.range(16, 128) as usize,
            };
            let job = GenJob::new(vec![2; len], kind, 0.8);
            if rng.below(2) == 0 {
                job.with_max_new_tokens(rng.range(4, 64) as usize)
            } else {
                job
            }
        })
        .collect()
}

fn main() {
    header("bench_batcher");
    let buckets = [1usize, 4, 8, 16, 32];
    let lens = [32usize, 64, 96, 128];
    for n in [4usize, 32, 128] {
        let js = jobs(n, n as u64);
        bench(&format!("plan_batches_{n}_jobs"), || {
            std::hint::black_box(plan_batches(&js, &buckets, &lens, 32));
        });
    }
}
