//! End-to-end figure sweep over a real-shaped EvalTable: the offline
//! recomputation that regenerates Fig 1 (per paper table/figure bench
//! requirement). Uses a synthetic table of the same dimensions as the
//! real test split so the bench runs without artifacts.

use ttc::config::SweepConfig;
use ttc::costmodel::CostEstimate;
use ttc::data::Query;
use ttc::figures::{adaptive_point, CostSource, EvalTable};
use ttc::router::Lambdas;
use ttc::strategies::Strategy;
use ttc::util::bench::{bench, header};
use ttc::util::rng::Rng;

fn synth_table(n_queries: usize) -> EvalTable {
    let strategies = Strategy::enumerate(&ttc::config::SpaceConfig::default());
    let mut rng = Rng::new(3, 0);
    let mut queries = Vec::new();
    let mut acc = Vec::new();
    let mut tokens = Vec::new();
    let mut latency = Vec::new();
    let mut probs = Vec::new();
    for qi in 0..n_queries {
        queries.push(Query {
            id: format!("b-{qi}"),
            query: "Q:1+1=?\n".into(),
            answer: "2".into(),
            k: 2 + qi % 6,
        });
        let row_a: Vec<f64> = strategies.iter().map(|_| rng.f64()).collect();
        acc.push(row_a.clone());
        tokens.push(strategies.iter().map(|s| 60.0 * s.n as f64).collect());
        latency.push(strategies.iter().map(|s| 200.0 * s.width as f64).collect());
        probs.push(row_a);
    }
    let cost_estimates: Vec<CostEstimate> = strategies
        .iter()
        .map(|s| CostEstimate {
            tokens: 60.0 * s.n as f64,
            latency_ms: 200.0 * s.width as f64,
        })
        .collect();
    EvalTable {
        queries,
        strategies,
        acc,
        tokens,
        latency,
        probs,
        cost_estimates,
    }
}

fn main() {
    header("bench_fig1");
    let table = synth_table(160); // the real test-split size
    let sweep = SweepConfig::default();

    bench("adaptive_point_160q", || {
        std::hint::black_box(adaptive_point(
            &table,
            Lambdas::new(1e-4, 1e-5),
            CostSource::Model,
        ));
    });

    bench("fig1a_full_sweep", || {
        let mut total = 0.0;
        for &ll in &sweep.fixed_lambda_l {
            for &lt in &sweep.lambda_t {
                let (a, _, _, _) =
                    adaptive_point(&table, Lambdas::new(lt, ll), CostSource::Model);
                total += a;
            }
        }
        std::hint::black_box(total);
    });

    bench("fig78_oracle_sweep", || {
        let mut total = 0.0;
        for &lt in &sweep.lambda_t {
            let (a, _, _, _) =
                adaptive_point(&table, Lambdas::new(lt, 0.0), CostSource::Oracle);
            total += a;
        }
        std::hint::black_box(total);
    });
}
