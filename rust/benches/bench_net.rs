//! Wire hot path: codec micro-benches (JSON vs the TTCB binary codec on
//! a realistic 32-row generate envelope) plus a multiplexed loopback
//! pool workload. Everything here rides the sim backend and in-process
//! transports, so it runs (and its stats gate) on every checkout.
//!
//! Gated stats (see `benches/baseline.json`):
//! * `wire_bytes_ratio_ttcb_vs_json` — ceiling 0.5: TTCB must encode
//!   the generate envelope in at most half the JSON bytes;
//! * `mux_inflight_peak` — floor 1: the shared connection must actually
//!   carry correlation-id-tagged calls.

use ttc::config::{BackendKind, Config, WireCodec};
use ttc::engine::EnginePool;
use ttc::net::{
    LoopbackEngineServer, MuxTransport, NetMetrics, RemoteBackend, RemoteConfig, Serializer,
    JSON, TTCB,
};
use ttc::strategies::stepper::{Stepper, Ticket};
use ttc::strategies::{Budget, Executor, Strategy};
use ttc::util::bench::{bench, header};
use ttc::util::clock;
use ttc::util::json::Value;

fn main() {
    header("bench_net");
    codec_bench();
    mux_bench();
}

/// A wire-realistic generate request: `rows` prompts of `len` tokens
/// each, ids spread over a 32k vocab (the regime where JSON's decimal
/// digits cost the most against TTCB's varint token runs).
fn generate_envelope(rows: usize, len: usize) -> Value {
    let prompts: Vec<Value> = (0..rows)
        .map(|i| {
            Value::Arr(
                (0..len)
                    .map(|j| Value::from(((i * 37 + j * 101) % 32_000) as u64))
                    .collect(),
            )
        })
        .collect();
    Value::obj()
        .with("op", "generate")
        .with("kind", "full")
        .with("temperature", 0.8)
        .with("bucket", 32usize)
        .with("id", 12_345usize)
        .with("prompts", Value::Arr(prompts))
}

fn codec_bench() {
    let envelope = generate_envelope(32, 48);
    let json_bytes = JSON.encode(&envelope).expect("json encode");
    let ttcb_bytes = TTCB.encode(&envelope).expect("ttcb encode");
    // sanity: the codecs must agree before we time them
    assert_eq!(
        JSON.decode(&json_bytes).unwrap(),
        TTCB.decode(&ttcb_bytes).unwrap(),
        "codecs must roundtrip to the same value"
    );

    bench("codec_json_encode_32row", || {
        std::hint::black_box(JSON.encode(&envelope).unwrap());
    });
    bench("codec_ttcb_encode_32row", || {
        std::hint::black_box(TTCB.encode(&envelope).unwrap());
    });
    bench("codec_json_decode_32row", || {
        std::hint::black_box(JSON.decode(&json_bytes).unwrap());
    });
    bench("codec_ttcb_decode_32row", || {
        std::hint::black_box(TTCB.decode(&ttcb_bytes).unwrap());
    });

    println!("stat,wire_bytes_per_call_json,{}", json_bytes.len());
    println!("stat,wire_bytes_per_call_ttcb,{}", ttcb_bytes.len());
    println!(
        "stat,wire_bytes_ratio_ttcb_vs_json,{}",
        ttcb_bytes.len() as f64 / json_bytes.len() as f64
    );
}

/// Multiplexed remote pool: 4 client engine slots sharing ONE loopback
/// connection (binary codec negotiated), driving concurrent beam
/// requests into a 2-engine sim fleet. The in-flight peak proves calls
/// actually overlapped on the shared socket instead of serializing.
fn mux_bench() {
    let mut server_cfg = Config::default();
    server_cfg.engine.backend = BackendKind::Sim;
    server_cfg.engine.sim_clock = true;
    server_cfg.engine.engines = 2;
    server_cfg.engine.wire_codec = WireCodec::Binary;
    // loopback-only exception (docs/remote.md): client and server live
    // in one process, so both may share one sim clock
    let clock = clock::sim_clock();
    let (connector, _server) =
        LoopbackEngineServer::spawn_with_clock(&server_cfg, clock.clone()).expect("server");
    let transport = MuxTransport::new(
        Box::new(connector),
        RemoteConfig {
            retries: 1,
            backoff_ms: 1.0,
            wire_codec: WireCodec::Binary,
            ..RemoteConfig::default()
        },
        NetMetrics::new(),
    );
    let mut client_cfg = Config::default();
    client_cfg.engine.engines = 4;
    let pool = EnginePool::start_with_factories(&client_cfg, clock.clone(), "remote backend", |_| {
        RemoteBackend::mux_factory(transport.clone(), clock.clone())
    })
    .expect("mux pool start");
    let executor = Executor::new(pool.handle(), pool.clock.clone(), 0.0);

    bench("remote_loopback_mux_4x", || {
        let mut stepper = Stepper::new(executor.clone());
        for i in 0..8u64 {
            stepper
                .admit(Ticket {
                    query: format!("Q:7+{i}-2+8=?\n"),
                    strategy: Strategy::beam(4, 2, 12),
                    budget: Budget::unlimited(),
                    tag: i,
                })
                .unwrap();
        }
        stepper.run_to_completion().unwrap();
        std::hint::black_box(stepper.drain_completed());
    });

    let m = transport.metrics();
    println!("stat,mux_inflight_peak,{}", m.mux_inflight_peak.get());
    let calls = m.frames_sent.get().max(1);
    println!(
        "stat,wire_bytes_per_call,{}",
        m.bytes_sent.get() as f64 / calls as f64
    );
    println!("stat,wire_bytes_saved_vs_json,{}", m.bytes_saved_vs_json.get());
    println!("# mux net metrics: {}", m.to_json().dumps());
}
