//! End-to-end engine throughput: batched generation through the AOT'd
//! executables (the system's FLOP budget lives here), plus per-method
//! strategy latency for every registered decoding method — the bench
//! trajectory that tracks how `mv_early` / `beam_latency` compare to the
//! seed four. Requires `make artifacts`; prints SKIP lines otherwise so
//! `cargo bench` stays green in fresh checkouts.

use ttc::config::{BackendKind, Config};
use ttc::engine::{Engine, EnginePool, GenJob, GenKind};
use ttc::strategies::stepper::{Stepper, Ticket};
use ttc::strategies::{registry, Budget, Executor, Strategy};
use ttc::tokenizer::Tokenizer;
use ttc::util::bench::{bench, header};

fn main() {
    header("bench_engine");
    std::env::set_var("TTC_BENCH_SECONDS", std::env::var("TTC_BENCH_SECONDS").unwrap_or("6".into()));
    let cfg = Config::default();
    if cfg.paths.artifacts.join("hlo_index.json").exists() {
        device_benches(&cfg);
    } else {
        println!("bench,SKIP_no_artifacts,0,0,0,0");
    }
    // the pool bench rides the artifact-free sim backend, so it runs
    // (and its balance stat gates) on every checkout
    pool_bench();
    // continuous slot-table path: staggered arrivals on a sim pool, so
    // the slot-occupancy / live-retirement stats gate on every checkout
    continuous_bench();
    // cross-request cache tier: shared-stem workload, sim backend, so
    // the hit-rate stats gate on every checkout too
    cache_bench();
    // the remote bench rides the loopback transport (full wire
    // protocol, no sockets), so it also runs everywhere
    remote_bench();
    // agentic chain tier: shared chain budgets on a sim pool, so the
    // goodput / cross-step grant stats gate on every checkout
    chain_bench();
}

/// Chain-tier workload: 4 concurrent 3-step chains, each under one
/// shared token budget, interleaved through `run_traffic` on a
/// 2-engine sim pool. Every chain's cheap first step underspends its
/// nominal share, so the allocator re-splits the surplus into later
/// steps — the two stats the bench gate floors (`chain_goodput` and
/// `chain_realloc_grants`) assert the banking path keeps working.
fn chain_bench() {
    use ttc::server::chain::ChainSpec;
    use ttc::server::driver::{self, Mode};
    use ttc::taskgen::ChainProblem;

    let mut cfg = Config::default();
    cfg.engine.backend = BackendKind::Sim;
    cfg.engine.sim_clock = true;
    cfg.engine.engines = 2;
    let pool = EnginePool::start(&cfg).expect("sim pool start (chains)");
    let executor = Executor::new(pool.handle(), pool.clock.clone(), 0.0);
    let mode = Mode::Static(Strategy::mv(2));
    let chains: Vec<ChainSpec> = (0..4)
        .map(|i| ChainSpec {
            id: format!("bench-c{i}"),
            arrival_ms: 0.0,
            // ample shared pool: the chain completes, but the equal
            // per-step nominals leave the first step's surplus to bank
            budget: Budget::unlimited().with_max_tokens(400),
            steps: ["7+8-5*2", "max(3,8,5)", "1+2+3"]
                .iter()
                .map(|e| ChainProblem::parse_expr(e).expect("valid step expr"))
                .collect(),
        })
        .collect();
    let run = || driver::run_traffic(&executor, &mode, Vec::new(), chains.clone(), 4).unwrap();
    bench("chain_4x_shared_budget", || {
        std::hint::black_box(run());
    });
    let report = run();
    let chain = report.chain.as_ref().expect("chain report section");
    println!(
        "stat,chain_goodput,{}",
        chain.req_f64("goodput").unwrap_or(0.0)
    );
    println!(
        "stat,chain_realloc_grants,{}",
        chain.req_f64("realloc_grants").unwrap_or(0.0)
    );
    println!("# chain report section: {}", chain.dumps());
}

/// Cross-request cache workload: 8 concurrent requests sharing one stem
/// (identical query, temp-0 beam decoding) on a 2-engine sim pool with
/// the cache tier enabled. Repeated requests replay cached rows instead
/// of re-decoding, so the run emits the two stats the bench gate floors:
/// `cache_hit_fraction` and `decode_steps_saved`.
fn cache_bench() {
    let mut cfg = Config::default();
    cfg.engine.backend = BackendKind::Sim;
    cfg.engine.sim_clock = true;
    cfg.engine.engines = 2;
    cfg.engine.cache.enabled = true;
    let pool = EnginePool::start(&cfg).expect("sim pool start (cache)");
    let executor = Executor::new(pool.handle(), pool.clock.clone(), 0.0);
    bench("cached_8x_shared_stem", || {
        let mut stepper = Stepper::new(executor.clone());
        for i in 0..8u64 {
            stepper
                .admit(Ticket {
                    // the shared stem: every request asks the same query
                    query: "Q:7+3-2+8=?\n".to_string(),
                    strategy: Strategy::beam(4, 2, 12),
                    budget: Budget::unlimited(),
                    tag: i,
                })
                .unwrap();
        }
        stepper.run_to_completion().unwrap();
        std::hint::black_box(stepper.drain_completed());
    });
    let report = pool.report();
    let cache = report.req("cache").expect("cache report section");
    println!(
        "stat,cache_hit_fraction,{}",
        cache.req_f64("hit_fraction").unwrap_or(0.0)
    );
    println!(
        "stat,decode_steps_saved,{}",
        cache.req_f64("decode_steps_saved").unwrap_or(0.0)
    );
    println!("# cache pool report: {}", report.dumps());
}

/// Sharded-pool workload: 4 concurrent beam requests multiplexed by the
/// stepper across a 2-engine sim pool. Emits the placement-balance stat
/// (`max/min` per-engine rows served) the bench gate holds a ceiling on.
fn pool_bench() {
    let mut cfg = Config::default();
    cfg.engine.backend = BackendKind::Sim;
    cfg.engine.sim_clock = true;
    cfg.engine.engines = 2;
    let pool = EnginePool::start(&cfg).expect("sim pool start");
    let executor = Executor::new(pool.handle(), pool.clock.clone(), 0.0);
    bench("pool_2x_beam_concurrent", || {
        let mut stepper = Stepper::new(executor.clone());
        for i in 0..4u64 {
            stepper
                .admit(Ticket {
                    query: format!("Q:7+{i}-2+8=?\n"),
                    strategy: Strategy::beam(4, 2, 12),
                    budget: Budget::unlimited(),
                    tag: i,
                })
                .unwrap();
        }
        stepper.run_to_completion().unwrap();
        std::hint::black_box(stepper.drain_completed());
    });
    println!("stat,pool_balance_ratio,{}", pool.balance_ratio());
    println!("# pool report: {}", pool.report().dumps());
}

/// Continuous-batching workload: 8 staggered majority-vote requests on
/// a 2-engine sim pool (the continuous slot-table path is the default).
/// Tickets are admitted with stepper pumps in between, so later
/// requests land while earlier sessions are mid-decode; half the
/// tickets carry a token cap far below their natural output, so rows
/// are retired live with decode work genuinely unspent. After the
/// timed runs, a dedicated single engine is probed with a
/// short-row/long-row session plus a trailing one-row request until a
/// mid-decode admission registers — the three stats the bench gate
/// floors (`slot_occupancy`, `decode_steps_saved_live`,
/// `mid_decode_admits`) then always reflect the real mechanisms.
fn continuous_bench() {
    let mut cfg = Config::default();
    cfg.engine.backend = BackendKind::Sim;
    cfg.engine.sim_clock = true;
    cfg.engine.engines = 2;
    let pool = EnginePool::start(&cfg).expect("sim pool start (continuous)");
    let executor = Executor::new(pool.handle(), pool.clock.clone(), 0.0);
    bench("continuous_8x_staggered", || {
        let mut stepper = Stepper::new(executor.clone());
        for i in 0..8u64 {
            stepper
                .admit(Ticket {
                    query: format!("Q:9-{}*2+7=?\n", i % 9),
                    strategy: Strategy::mv(4),
                    // the capped half halts mid-decode with natural
                    // output left — live retirement frees their slots
                    budget: if i % 2 == 0 {
                        Budget::unlimited().with_max_tokens(8)
                    } else {
                        Budget::unlimited()
                    },
                    tag: i,
                })
                .unwrap();
            // pump between admissions: the next ticket's jobs arrive
            // while the earlier sessions are already decoding
            for _ in 0..3 {
                let _ = stepper.advance(Some(std::time::Duration::from_micros(50)));
            }
        }
        stepper.run_to_completion().unwrap();
        std::hint::black_box(stepper.drain_completed());
    });

    // mid-decode admission probe: a pool would place the trailing
    // request on the *other* engine, so this runs on one dedicated
    // engine. The 2-row session (short + long natural output) keeps
    // free slots and a live row for ~dozens of decode steps; a one-row
    // request landing in that window joins the running session. The
    // window is wall-clock, hence the bounded retry loop.
    let mut ecfg = Config::default();
    ecfg.engine.backend = BackendKind::Sim;
    ecfg.engine.sim_clock = true;
    let engine = Engine::start(&ecfg).expect("sim engine start (probe)");
    let h = engine.handle();
    let tok = Tokenizer::new();
    let short = tok.encode("Q:1+2=?\n").unwrap();
    let long = tok.encode("Q:9+8-7+6-5+4+3-2+1=?\n").unwrap();
    for _ in 0..200 {
        let a = h
            .submit_generate(
                vec![
                    GenJob::new(short.clone(), GenKind::Full, 0.0),
                    GenJob::new(long.clone(), GenKind::Full, 0.0),
                ],
                None,
            )
            .unwrap();
        std::thread::yield_now();
        let b = h
            .submit_generate(vec![GenJob::new(short.clone(), GenKind::Full, 0.0)], None)
            .unwrap();
        a.wait().unwrap();
        b.wait().unwrap();
        if engine.metrics.mid_decode_admits.get() > 0 {
            break;
        }
    }

    // aggregate the slot-table stats over the pool and the probe engine
    let (mut occupied, mut total, mut saved, mut admits, mut retired) = (0u64, 0u64, 0u64, 0u64, 0u64);
    let all = (0..pool.engines())
        .map(|i| pool.engine_metrics(i).clone())
        .chain(std::iter::once(engine.metrics.clone()));
    for m in all {
        occupied += m.slot_steps_occupied.get();
        total += m.slot_steps_total.get();
        saved += m.decode_steps_saved_live.get();
        admits += m.mid_decode_admits.get();
        retired += m.retired_rows.get();
    }
    println!("stat,slot_occupancy,{}", occupied as f64 / total.max(1) as f64);
    println!("stat,decode_steps_saved_live,{saved}");
    println!("stat,mid_decode_admits,{admits}");
    println!("# continuous retired_rows: {retired}");
    println!("# continuous pool report: {}", pool.report().dumps());
}

/// Remote-tier workload: 4 concurrent beam requests through a client
/// pool of 2 `RemoteBackend`s, each dialing its own loopback
/// `engine-serve` fleet (full framed protocol, in-process transport).
/// After the timed runs, one shard is killed mid-deployment and an
/// extra wave is driven through, so the reroute stat the bench gate
/// floors (`remote_reroutes >= 1`) always reflects a real failover.
fn remote_bench() {
    use ttc::net::{LoopbackEngineServer, NetMetrics, RemoteBackend, RemoteConfig};
    use ttc::util::clock;

    let mut cfg = Config::default();
    cfg.engine.backend = BackendKind::Sim;
    cfg.engine.sim_clock = true;
    cfg.engine.engines = 1;
    // loopback-only exception (docs/remote.md): client and servers live
    // in one process, so all of them may share one sim clock
    let clock = clock::sim_clock();
    let (conn_a, _server_a) =
        LoopbackEngineServer::spawn_with_clock(&cfg, clock.clone()).expect("server a");
    let (conn_b, mut server_b) =
        LoopbackEngineServer::spawn_with_clock(&cfg, clock.clone()).expect("server b");
    let connectors = [conn_a, conn_b];
    let metrics = NetMetrics::new();
    let remote_cfg = RemoteConfig {
        retries: 1,
        backoff_ms: 1.0,
        ..RemoteConfig::default()
    };
    let mut client_cfg = Config::default();
    client_cfg.engine.engines = 2;
    let pool = EnginePool::start_with_factories(&client_cfg, clock.clone(), "remote backend", |i| {
        RemoteBackend::factory(
            connectors[i % 2].clone(),
            remote_cfg.clone(),
            clock.clone(),
            metrics.clone(),
        )
    })
    .expect("remote pool start");
    let executor = Executor::new(pool.handle(), pool.clock.clone(), 0.0);

    let wave = |executor: &Executor| {
        let mut stepper = Stepper::new(executor.clone());
        for i in 0..4u64 {
            stepper
                .admit(Ticket {
                    query: format!("Q:7+{i}-2+8=?\n"),
                    strategy: Strategy::beam(4, 2, 12),
                    budget: Budget::unlimited(),
                    tag: i,
                })
                .unwrap();
        }
        stepper.run_to_completion().unwrap();
        std::hint::black_box(stepper.drain_completed());
    };
    bench("remote_loopback_2x_beam", || wave(&executor));

    // kill one shard and drive a wave through the survivor: the pool
    // must fail the dead slot over, not error
    server_b.kill();
    wave(&executor);

    println!(
        "stat,remote_frames,{}",
        metrics.frames_sent.get() + metrics.frames_received.get()
    );
    let report = pool.report();
    println!(
        "stat,remote_reroutes,{}",
        report.req_f64("rerouted_submits").unwrap_or(0.0)
    );
    println!("# remote pool report: {}", report.dumps());
    println!("# remote net metrics: {}", metrics.to_json().dumps());
}

fn device_benches(cfg: &Config) {
    let engine = Engine::start(cfg).expect("engine start");
    let handle = engine.handle();
    let tok = Tokenizer::new();
    let prompt = tok.encode("Q:7+8-2+8=?\nS:").unwrap();

    for n in [1usize, 4, 16] {
        let jobs: Vec<GenJob> = (0..n)
            .map(|_| GenJob::new(prompt.clone(), GenKind::Full, 0.8))
            .collect();
        let mut tokens_out = 0usize;
        let mean_ns = bench(&format!("generate_b{n}"), || {
            let r = handle.generate(jobs.clone()).unwrap();
            tokens_out = r.iter().map(|x| x.tokens.len()).sum();
            std::hint::black_box(&r);
        });
        let tps = tokens_out as f64 / (mean_ns / 1e9);
        println!("# generate_b{n}: ~{tokens_out} tokens/call, {tps:.0} tok/s");
    }

    // beam-style chunk call
    let chunk_prompt = tok.encode("Q:7+8-2+8=?\nS:7+8=5;").unwrap();
    let jobs: Vec<GenJob> = (0..8)
        .map(|_| GenJob::new(chunk_prompt.clone(), GenKind::Chunk, 0.8))
        .collect();
    bench("chunk_b8", || {
        std::hint::black_box(handle.generate(jobs.clone()).unwrap());
    });

    // mid-call preemption overhead: the same batched call with a spent
    // deadline — measures the engine's preempt/accounting path, which
    // must stay cheap relative to the call itself
    let capped: Vec<GenJob> = (0..8)
        .map(|_| GenJob::new(prompt.clone(), GenKind::Full, 0.8).with_max_new_tokens(4))
        .collect();
    bench("generate_b8_cap4_preempt", || {
        std::hint::black_box(handle.generate(capped.clone()).unwrap());
    });

    // embeddings (router path)
    let queries: Vec<Vec<u32>> = (0..8).map(|_| tok.encode("Q:7+8-2=?\n").unwrap()).collect();
    bench("embed_pool_b8", || {
        std::hint::black_box(
            handle
                .embed(ttc::engine::EmbedKind::Pool, queries.clone())
                .unwrap(),
        );
    });

    // per-method strategy latency: one bench per registered decoding
    // method at its default parameters (the bench trajectory captures
    // every method, not just the seed four)
    let executor = Executor::new(handle.clone(), engine.clock.clone(), 0.8);
    let query = "Q:7+8-2+8=?\n";
    for m in registry::all() {
        let s = Strategy::new(m.name(), m.default_params());
        bench(&format!("strategy_{}", s.id()), || {
            std::hint::black_box(executor.run(&s, query).unwrap());
        });
    }

    // deadline-aware beam under a tight budget: the latency ceiling the
    // serving path can now enforce mid-strategy
    let tight = Budget::unlimited().with_deadline_ms(250.0);
    let s = Strategy::beam_latency(4, 2, 12);
    bench("strategy_beam_latency_deadline250ms", || {
        std::hint::black_box(
            executor
                .run_budgeted(&s, query, tight.clone())
                .unwrap(),
        );
    });

    // concurrent mixed workload: 4 workers each alternating generate →
    // PRM score, the beam-family cadence under multi-worker serving.
    // The scheduler coalesces same-op messages from different workers
    // into shared bucket-shaped calls, so the padded-row fractions
    // reported below drop vs serving each worker's small batch alone.
    let prm_prefix = tok.encode("Q:7+8-2+8=?\nS:7+8=5;5-2=3;").unwrap();
    bench("mixed_concurrent_4w", || {
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let handle = handle.clone();
                let prompt = prompt.clone();
                let prm_prefix = prm_prefix.clone();
                scope.spawn(move || {
                    let jobs: Vec<GenJob> = (0..4)
                        .map(|_| GenJob::new(prompt.clone(), GenKind::Full, 0.8))
                        .collect();
                    handle.generate(jobs).unwrap();
                    let prefixes: Vec<Vec<u32>> = (0..8).map(|_| prm_prefix.clone()).collect();
                    handle.prm_score(prefixes).unwrap();
                });
            }
        });
    });

    // stepped beam concurrency: 4 beam requests multiplexed onto the
    // engine by the continuation executor — one pump thread, no
    // thread-per-request. The machines' round-k expansions are
    // submitted together, so the scheduler coalesces them into shared
    // bucket-shaped calls; the stat below gates that the stepped
    // workload actually coalesces (floor asserted by bench_gate.sh).
    let coalesced_before = {
        let info = handle.info().unwrap();
        info.req("metrics")
            .and_then(|m| m.req_f64("coalesced_generates"))
            .unwrap_or(0.0)
    };
    bench("beam_4x_concurrent_stepped", || {
        let mut stepper = Stepper::new(executor.clone());
        for i in 0..4u64 {
            stepper
                .admit(Ticket {
                    query: format!("Q:7+{i}-2+8=?\n"),
                    strategy: Strategy::beam(4, 2, 12),
                    budget: Budget::unlimited(),
                    tag: i,
                })
                .unwrap();
        }
        stepper.run_to_completion().unwrap();
        std::hint::black_box(stepper.drain_completed());
    });
    let coalesced_after = {
        let info = handle.info().unwrap();
        info.req("metrics")
            .and_then(|m| m.req_f64("coalesced_generates"))
            .unwrap_or(0.0)
    };
    println!(
        "stat,stepper_coalesced_generates,{}",
        coalesced_after - coalesced_before
    );

    // machine-parseable padding/coalescing stats for the bench gate
    // (`stat,<name>,<value>` — picked up into BENCH_<sha>.json)
    let info = handle.info().unwrap();
    let metrics = info.req("metrics").expect("engine metrics");
    for key in [
        "padding_waste",
        "prm_padding_waste",
        "embed_padding_waste",
        "sched_rounds",
        "coalesced_msgs",
        "coalesced_prm",
        "coalesced_generates",
    ] {
        if let Ok(v) = metrics.req_f64(key) {
            println!("stat,{key},{v}");
        }
    }
    println!("# engine info: {}", info.dumps());
}
