//! Probe training throughput: the AOT'd Adam step driven from rust.
//! Bounds how fast `ttc train-probe` converges. Requires artifacts.

use ttc::config::Config;
use ttc::engine::Engine;
use ttc::util::bench::{bench, header};
use ttc::util::rng::Rng;

fn main() {
    header("bench_probe_train");
    let cfg = Config::default();
    if !cfg.paths.artifacts.join("hlo_index.json").exists() {
        println!("bench,SKIP_no_artifacts,0,0,0,0");
        return;
    }
    let engine = Engine::start(&cfg).expect("engine start");
    let handle = engine.handle();
    let info = handle.info().unwrap();
    let f = info
        .req("shapes")
        .unwrap()
        .req_usize("probe_features")
        .unwrap();

    let mut rng = Rng::new(5, 0);
    let feats: Vec<Vec<f32>> = (0..256)
        .map(|_| (0..f).map(|_| rng.f32()).collect())
        .collect();
    let labels: Vec<f32> = (0..256).map(|_| (rng.below(4) as f32) / 3.0).collect();

    bench("probe_fwd_256_rows", || {
        std::hint::black_box(handle.probe_fwd(feats.clone()).unwrap());
    });

    bench("probe_train_1_epoch_256_rows", || {
        std::hint::black_box(
            handle
                .probe_train(
                    feats.clone(),
                    labels.clone(),
                    feats[..32].to_vec(),
                    labels[..32].to_vec(),
                    1,
                    9,
                )
                .unwrap(),
        );
    });
}
