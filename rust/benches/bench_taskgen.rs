//! Substrate bench: synthetic problem generation + surface forms.

use ttc::taskgen::Problem;
use ttc::tokenizer::Tokenizer;
use ttc::util::bench::{bench, header};
use ttc::util::rng::Rng;

fn main() {
    header("bench_taskgen");
    let mut rng = Rng::new(7, 0);
    bench("problem_sample_k5", || {
        std::hint::black_box(Problem::sample(&mut rng, 5));
    });
    let p = Problem::sample(&mut Rng::new(7, 1), 7);
    bench("problem_document_k7", || {
        std::hint::black_box(p.document());
    });
    let tok = Tokenizer::new();
    let doc = p.document();
    bench("tokenize_document_k7", || {
        std::hint::black_box(tok.encode(&doc).unwrap());
    });
}
