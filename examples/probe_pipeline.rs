//! The probe pipeline end to end on a miniature budget: collect a small
//! evaluation matrix, train the accuracy probe through the AOT'd Adam
//! step, Platt-calibrate, and print per-difficulty predictions — a
//! self-contained demonstration that the *rust* side owns the full
//! adaptive loop (python never sees the labels).
//!
//! ```bash
//! make artifacts && cargo run --release --example probe_pipeline
//! ```

use ttc::config::Config;
use ttc::data::Splits;
use ttc::engine::{EmbedKind, Engine};
use ttc::matrix;
use ttc::probe::{train_probe, FeatureBuilder};
use ttc::strategies::{Executor, Strategy};

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    // miniature strategy space + query budget so this finishes in minutes
    cfg.space.mv_ns = vec![1, 4];
    cfg.space.bon_ns = vec![4];
    cfg.space.beam = vec![(2, 2, 12)];
    cfg.space.mv_early = vec![];
    cfg.space.extra = vec!["mv_early@4".into()];
    let engine = Engine::start(&cfg)?;
    let executor = Executor::new(engine.handle(), engine.clock.clone(), cfg.engine.temperature);
    let splits = Splits::load(&cfg.paths().data_dir())?;
    let strategies = Strategy::enumerate(&cfg.space);

    let tmp = std::env::temp_dir().join(format!("ttc_probe_pipeline_{}", std::process::id()));
    std::fs::create_dir_all(&tmp)?;
    let train_q = &splits.train[..16.min(splits.train.len())];
    let calib_q = &splits.calib[..12.min(splits.calib.len())];
    println!(
        "collecting {}×{} matrix (train) + {}×{} (calib)...",
        train_q.len(),
        strategies.len(),
        calib_q.len(),
        strategies.len()
    );
    let train_m = matrix::collect(
        &executor, train_q, "train", &strategies, 2, &tmp.join("m_train.jsonl"),
    )?;
    let calib_m = matrix::collect(
        &executor, calib_q, "calib", &strategies, 1, &tmp.join("m_calib.jsonl"),
    )?;

    let info = engine.handle().info()?;
    let features = info.req("shapes")?.req_usize("probe_features")?;
    let fb = FeatureBuilder::new(features - FeatureBuilder::aux_dim(), cfg.space.beam_max_rounds);
    let (probe, report) = train_probe(
        &engine.handle(),
        &train_m,
        &calib_m,
        train_q,
        calib_q,
        &fb,
        EmbedKind::Pool,
        &cfg.probe,
        7,
    )?;
    println!("probe report: {}", report.pretty());

    // show â_s(x) for an easy and a hard query across the space
    let tok = ttc::tokenizer::Tokenizer::new();
    for q in [&splits.test[0], &splits.test[splits.test.len() - 1]] {
        let emb = engine
            .handle()
            .embed(EmbedKind::Pool, vec![tok.encode(&q.query)?])?
            .remove(0);
        let qlen = tok.encode(&q.query)?.len();
        let feats: Vec<Vec<f32>> = strategies.iter().map(|s| fb.build(&emb, s, qlen)).collect();
        let probs = probe.predict(&engine.handle(), feats)?;
        println!("\nquery {} (k={}):", q.id, q.k);
        for (s, p) in strategies.iter().zip(probs) {
            println!("  â[{:<14}] = {p:.3}", s.id());
        }
    }
    std::fs::remove_dir_all(&tmp).ok();
    Ok(())
}
