//! End-to-end serving driver (the repo's E2E validation example).
//!
//! Loads the trained generator + PRM + calibrated probe, then serves a
//! batch of real test queries through the **query-adaptive router** under
//! Poisson arrivals, reporting accuracy, token cost, latency percentiles
//! and throughput — and contrasts it against a static strategy at the
//! same load.
//!
//! ```bash
//! make artifacts
//! cargo run --release --bin ttc -- collect            # evaluation matrix
//! cargo run --release --bin ttc -- train-probe        # probe + calibration
//! cargo run --release --example serve_adaptive
//! ```

use ttc::config::Config;
use ttc::costmodel::CostModel;
use ttc::data::Splits;
use ttc::engine::Engine;
use ttc::probe::{FeatureBuilder, ProbeCheckpoint};
use ttc::router::{Lambdas, Router};
use ttc::server::driver::{self, Mode};
use ttc::server::loadgen::{self, Arrivals};
use ttc::strategies::{Executor, Strategy};
use ttc::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let engine = Engine::start(&cfg)?;
    let executor = Executor::new(engine.handle(), engine.clock.clone(), cfg.engine.temperature);
    let splits = Splits::load(&cfg.paths().data_dir())?;

    // adaptive mode needs the trained probe + cost model
    let probe = ProbeCheckpoint::load(&cfg.paths.results.join("probe_pool"))?;
    probe.install(&engine.handle())?;
    let costs = CostModel::from_json(&ttc::util::json::parse(&std::fs::read_to_string(
        cfg.paths.results.join("cost_model.json"),
    )?)?)?;
    let info = engine.handle().info()?;
    let features = info.req("shapes")?.req_usize("probe_features")?;
    let fb = FeatureBuilder::new(features - 9, cfg.space.beam_max_rounds);
    let router = Router::new(Strategy::enumerate(&cfg.space), probe, costs, fb);

    // pre-compile every executable the adaptive mix can touch so live
    // requests never pay lazy XLA compilation
    driver::warmup(&executor, &router.strategies, &splits.test[0].query)?;

    let n_requests = std::env::var("TTC_SERVE_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);
    let rate = 0.5; // req/s — keeps the 1-core testbed below saturation
    let mut rng = Rng::new(cfg.seed, 0xAD);
    println!("== adaptive routing (λ_T=1e-4, λ_L=1e-5), {n_requests} reqs @ {rate}/s ==");
    let schedule = loadgen::schedule(
        &splits.test,
        n_requests,
        Arrivals::Poisson { rate },
        &mut rng,
    );
    let report = driver::run(
        &executor,
        &Mode::Adaptive(router, Lambdas::new(1e-4, 1e-5)),
        schedule,
        4,
    )?;
    report.log_summary("adaptive");
    println!("{}", report.to_json().pretty());

    println!("== static baseline (majority_vote@8), same load ==");
    let mut rng = Rng::new(cfg.seed, 0xAD); // same schedule
    let schedule = loadgen::schedule(
        &splits.test,
        n_requests,
        Arrivals::Poisson { rate },
        &mut rng,
    );
    let report = driver::run(&executor, &Mode::Static(Strategy::mv(8)), schedule, 4)?;
    report.log_summary("static mv@8");
    println!("{}", report.to_json().pretty());
    Ok(())
}
