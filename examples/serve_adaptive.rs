//! End-to-end serving driver (the repo's E2E validation example).
//!
//! Loads the trained generator + PRM + calibrated probe, then serves a
//! batch of real test queries through the **query-adaptive router** under
//! Poisson arrivals, reporting accuracy, token cost, latency percentiles
//! and throughput — contrasted against a static strategy at the same
//! load, and against the same adaptive mix with a **per-request
//! deadline** enforced *mid-strategy* (beam rounds visibly truncate:
//! watch `budget_exhausted_fraction` / `stopped_early_fraction` in the
//! report).
//!
//! ```bash
//! make artifacts
//! cargo run --release --bin ttc -- collect            # evaluation matrix
//! cargo run --release --bin ttc -- train-probe        # probe + calibration
//! cargo run --release --example serve_adaptive
//! ```

use ttc::config::Config;
use ttc::costmodel::CostModel;
use ttc::data::Splits;
use ttc::engine::Engine;
use ttc::probe::{FeatureBuilder, ProbeCheckpoint};
use ttc::router::{Lambdas, Router};
use ttc::server::driver::{self, Mode};
use ttc::server::loadgen::{self, Arrivals};
use ttc::strategies::{Budget, Executor, Strategy};
use ttc::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let engine = Engine::start(&cfg)?;
    let executor = Executor::new(engine.handle(), engine.clock.clone(), cfg.engine.temperature);
    let splits = Splits::load(&cfg.paths().data_dir())?;

    // adaptive mode needs the trained probe + cost model
    let probe = ProbeCheckpoint::load(&cfg.paths.results.join("probe_pool"))?;
    probe.install(&engine.handle())?;
    let costs = CostModel::from_json(&ttc::util::json::parse(&std::fs::read_to_string(
        cfg.paths.results.join("cost_model.json"),
    )?)?)?;
    let info = engine.handle().info()?;
    let features = info.req("shapes")?.req_usize("probe_features")?;
    let fb = FeatureBuilder::new(features - FeatureBuilder::aux_dim(), cfg.space.beam_max_rounds);
    let router = Router::new(Strategy::enumerate(&cfg.space), probe, costs, fb);

    // pre-compile every executable the adaptive mix can touch so live
    // requests never pay lazy XLA compilation
    driver::warmup(&executor, &router.strategies, &splits.test[0].query)?;
    let adaptive = Mode::Adaptive(router, Lambdas::new(1e-4, 1e-5));

    let n_requests = std::env::var("TTC_SERVE_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);
    let rate = 0.5; // req/s — keeps the 1-core testbed below saturation
    let make_schedule = |budget: Budget| {
        let mut rng = Rng::new(cfg.seed, 0xAD); // same schedule each block
        loadgen::schedule_budgeted(
            &splits.test,
            n_requests,
            Arrivals::Poisson { rate },
            budget,
            &mut rng,
        )
    };

    println!("== adaptive routing (λ_T=1e-4, λ_L=1e-5), {n_requests} reqs @ {rate}/s ==");
    let report = driver::run(&executor, &adaptive, make_schedule(Budget::unlimited()), 4)?;
    report.log_summary("adaptive");
    println!("{}", report.to_json().pretty());

    println!("== adaptive + per-request deadline (2000 ms, enforced mid-strategy) ==");
    let budget = Budget::unlimited().with_deadline_ms(2000.0);
    let report = driver::run(&executor, &adaptive, make_schedule(budget), 4)?;
    report.log_summary("adaptive+deadline");
    println!("{}", report.to_json().pretty());

    println!("== static baseline (majority_vote@8), same load ==");
    let report = driver::run(
        &executor,
        &Mode::Static(Strategy::mv(8)),
        make_schedule(Budget::unlimited()),
        4,
    )?;
    report.log_summary("static mv@8");
    println!("{}", report.to_json().pretty());
    Ok(())
}
