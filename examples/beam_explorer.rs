//! Beam-search hyperparameter explorer (the appendix A.5 / Fig 9
//! single-method setting): runs several (N, W, C) beam configurations on
//! a handful of hard queries and prints the accuracy/token/latency
//! profile of each, showing the tradeoff surface the beam-only adaptive
//! router optimizes over.
//!
//! ```bash
//! make artifacts && cargo run --release --example beam_explorer
//! ```

use ttc::config::Config;
use ttc::engine::Engine;
use ttc::strategies::{Executor, Strategy};
use ttc::taskgen::Problem;
use ttc::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let engine = Engine::start(&cfg)?;
    let executor = Executor::new(engine.handle(), engine.clock.clone(), cfg.engine.temperature);

    // hard problems (k = 6, 7): where the paper finds beam search shines
    let mut rng = Rng::new(0xBEA7, 0);
    let problems: Vec<Problem> = (0..6)
        .map(|i| Problem::sample(&mut rng, 6 + i % 2))
        .collect();

    let configs = [
        Strategy::beam(2, 2, 12),
        Strategy::beam(4, 2, 12),
        Strategy::beam(4, 4, 12),
        Strategy::beam(4, 2, 6), // smaller chunk: more PRM checkpoints
    ];

    println!(
        "{:<14} {:>8} {:>9} {:>11} {:>7}",
        "config", "acc", "tokens", "latency_ms", "calls"
    );
    for strategy in &configs {
        let mut correct = 0usize;
        let mut tokens = 0usize;
        let mut latency = 0.0f64;
        let mut calls = 0usize;
        for p in &problems {
            let o = executor.run(strategy, &p.query_text())?;
            correct += o.is_correct(&p.answer().to_string()) as usize;
            tokens += o.tokens;
            latency += o.latency_ms;
            calls += o.engine_calls;
        }
        let n = problems.len();
        println!(
            "{:<14} {:>8.2} {:>9.0} {:>11.0} {:>7}",
            strategy.id(),
            correct as f64 / n as f64,
            tokens as f64 / n as f64,
            latency / n as f64,
            calls / n,
        );
    }
    println!("\n(compare with majority_vote@4 on the same problems)");
    let mv = Strategy::mv(4);
    let mut correct = 0;
    let mut tokens = 0;
    let mut latency = 0.0;
    for p in &problems {
        let o = executor.run(&mv, &p.query_text())?;
        correct += o.is_correct(&p.answer().to_string()) as usize;
        tokens += o.tokens;
        latency += o.latency_ms;
    }
    println!(
        "{:<14} {:>8.2} {:>9.0} {:>11.0}",
        mv.id(),
        correct as f64 / problems.len() as f64,
        tokens as f64 / problems.len() as f64,
        latency / problems.len() as f64,
    );
    Ok(())
}
