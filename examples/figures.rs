//! Regenerate every figure in the paper from the collected matrices.
//! Thin wrapper over `ttc figures --fig all` so the reproduction entry
//! point is also a library example.
//!
//! ```bash
//! cargo run --release --example figures            # all figures
//! cargo run --release --example figures -- 1a      # one panel
//! ```

fn main() -> anyhow::Result<()> {
    let fig = std::env::args().nth(1).unwrap_or_else(|| "all".into());
    let args = vec!["figures".to_string(), "--fig".to_string(), fig];
    ttc::server::commands::cmd_figures(&args)?;
    println!("figures written under results/figures/");
    Ok(())
}
