//! Quickstart: load the AOT artifacts, ask one math query, and compare a
//! cheap strategy (majority voting @4) against beam search on the same
//! query — printing answers, token costs and latencies.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use ttc::config::Config;
use ttc::engine::Engine;
use ttc::strategies::{Executor, Strategy};
use ttc::taskgen::Problem;
use ttc::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    if !cfg.paths.artifacts.join("hlo_index.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }

    // 1. start the engine (loads weights, lazily compiles executables)
    let engine = Engine::start(&cfg)?;
    let executor = Executor::new(engine.handle(), engine.clock.clone(), cfg.engine.temperature);

    // 2. sample a problem the generator has never seen
    let mut rng = Rng::new(0xD15C0, 0);
    let problem = Problem::sample(&mut rng, 5);
    let query = problem.query_text();
    println!("query : {}", query.trim());
    println!("truth : {}", problem.answer());

    // 3. run two strategies on it
    for strategy in [Strategy::mv(4), Strategy::beam(4, 2, 12)] {
        let outcome = executor.run(&strategy, &query)?;
        println!(
            "{:<14} -> answer {:<4} ({}) | {:>4} tokens | {:>7.0} ms | {} engine calls",
            strategy.id(),
            outcome.answer.clone().unwrap_or_else(|| "?".into()),
            if outcome.is_correct(&problem.answer().to_string()) {
                "correct"
            } else {
                "wrong"
            },
            outcome.tokens,
            outcome.latency_ms,
            outcome.engine_calls,
        );
    }

    // 4. engine diagnostics
    println!("\nengine: {}", engine.handle().info()?.pretty());
    Ok(())
}
